"""Decoder-only transformer stack: dense / MoE / VLM families.

One implementation covers qwen2-7b, gemma3-27b, starcoder2-15b,
qwen1.5-110b, mixtral-8x7b, qwen2-moe-a2.7b and llama-3.2-vision-11b:

* **scan-over-layers** keeps HLO size O(1) in depth (512-device compiles);
* **local:global interleave** (gemma3): one uniform layer stack with a
  per-layer ``is_global`` flag; ``lax.cond`` selects windowed vs. full
  attention.  Decode uses a *dual cache*: rolled (B, W, K, Dh) buffers for
  every layer (xs of the scan) plus full-length caches for the few global
  layers (carry, indexed by a per-layer global-slot);
* **sliding-window everywhere** (mixtral): single rolled cache of size W;
* **cross-attention interleave** (llama-vision): self layers grouped, one
  gated cross-attn layer after every ``cross_attn_every`` self layers.

Simplifications recorded in DESIGN.md: RMSNorm for all archs (starcoder2
ships LayerNorm), no QK-norm (gemma3), single rope base.
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import named
from repro.models import attention as attn
from repro.models.config import ModelConfig
from repro.models.layers import (PSpec, mlp_apply, mlp_specs, rms_norm,
                                 stack_tree)
from repro.models.moe import moe_apply, moe_specs


# --------------------------------------------------------------------------
# Specs
# --------------------------------------------------------------------------


def block_specs(cfg: ModelConfig) -> dict[str, Any]:
    d = cfg.d_model
    s: dict[str, Any] = {
        "ln1": PSpec((d,), (None,), init="zeros"),
        "attn": attn.attn_specs(cfg),
        "ln2": PSpec((d,), (None,), init="zeros"),
    }
    if cfg.family == "moe":
        s["moe"] = moe_specs(cfg)
    else:
        s["mlp"] = mlp_specs(d, cfg.d_ff, cfg.mlp)
    return s


def cross_block_specs(cfg: ModelConfig) -> dict[str, Any]:
    d = cfg.d_model
    return {
        "ln1": PSpec((d,), (None,), init="zeros"),
        "attn": attn.attn_specs(cfg, cross=True),
        "gate_attn": PSpec((), (), init="zeros"),
        "ln2": PSpec((d,), (None,), init="zeros"),
        "mlp": mlp_specs(d, cfg.d_ff, cfg.mlp),
        "gate_mlp": PSpec((), (), init="zeros"),
    }


def decoder_specs(cfg: ModelConfig) -> dict[str, Any]:
    d, v, l = cfg.d_model, cfg.padded_vocab, cfg.n_layers
    specs: dict[str, Any] = {
        "embed": PSpec((v, d), ("vocab", "fsdp"), init="small"),
        "ln_f": PSpec((d,), (None,), init="zeros"),
        "layers": stack_tree(block_specs(cfg), l),
    }
    if not cfg.tie_embeddings:
        specs["head"] = PSpec((d, v), ("fsdp", "vocab"))
    if cfg.family == "vlm":
        if l % cfg.cross_attn_every:
            raise ValueError("n_layers must divide cross_attn_every groups")
        g = l // cfg.cross_attn_every
        specs["cross_layers"] = stack_tree(cross_block_specs(cfg), g)
    return specs


# --------------------------------------------------------------------------
# Blocks
# --------------------------------------------------------------------------


def _ffn(lp: dict, x: jax.Array, cfg: ModelConfig, train: bool
         ) -> tuple[jax.Array, jax.Array]:
    if cfg.family == "moe":
        cf = cfg.moe_cf_train if train else cfg.moe_cf_eval
        return moe_apply(lp["moe"], x, cfg, capacity_factor=cf)
    return mlp_apply(lp["mlp"], x, cfg.mlp), jnp.zeros((), jnp.float32)


def block_full(lp: dict, x: jax.Array, cfg: ModelConfig, *,
               positions: jax.Array, window: Optional[int],
               train: bool = True
               ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Full-sequence block. Returns (x, k, v, aux_loss)."""
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    a, k, v = attn.attn_full(lp["attn"], h, cfg, positions=positions,
                             window=window)
    x = named(x + a, "batch", "seq", None)
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    m, aux = _ffn(lp, h, cfg, train)
    x = named(x + m, "batch", "seq", None)
    return x, k, v, aux


def block_decode(lp: dict, x: jax.Array, kc: jax.Array, vc: jax.Array,
                 pos: jax.Array, cfg: ModelConfig, *, rolled: bool,
                 window: Optional[int]
                 ) -> tuple[jax.Array, jax.Array, jax.Array]:
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    a, kc, vc = attn.attn_decode(lp["attn"], h, kc, vc, pos, cfg,
                                 rolled=rolled, window=window)
    x = named(x + a, "batch", "seq", None)
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    m, _ = _ffn(lp, h, cfg, train=False)
    return named(x + m, "batch", "seq", None), kc, vc


def block_decode_paged(lp: dict, x: jax.Array, kc: jax.Array, vc: jax.Array,
                       block_tables: jax.Array, pos: jax.Array,
                       cfg: ModelConfig,
                       active: Optional[jax.Array] = None
                       ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """block_decode against one layer's paged KV blocks."""
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    a, kc, vc = attn.attn_decode_paged(lp["attn"], h, kc, vc,
                                       block_tables, pos, cfg, active)
    x = named(x + a, "batch", "seq", None)
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    m, _ = _ffn(lp, h, cfg, train=False)
    return named(x + m, "batch", "seq", None), kc, vc


def block_decode_paged_quant(lp: dict, x: jax.Array, kc, vc, ksc, vsc,
                             block_tables: jax.Array, pos: jax.Array,
                             cfg: ModelConfig,
                             active: Optional[jax.Array] = None):
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    a, kc, vc, ksc, vsc = attn.attn_decode_paged_quant(
        lp["attn"], h, kc, vc, ksc, vsc, block_tables, pos, cfg, active)
    x = named(x + a, "batch", "seq", None)
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    m, _ = _ffn(lp, h, cfg, train=False)
    return named(x + m, "batch", "seq", None), kc, vc, ksc, vsc


def block_decode_quant(lp: dict, x: jax.Array, kc, vc, ksc, vsc,
                       pos: jax.Array, cfg: ModelConfig):
    """block_decode against int8 caches (§Perf D)."""
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    a, kc, vc, ksc, vsc = attn.attn_decode_quant(lp["attn"], h, kc, vc,
                                                 ksc, vsc, pos, cfg)
    x = named(x + a, "batch", "seq", None)
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    m, _ = _ffn(lp, h, cfg, train=False)
    return named(x + m, "batch", "seq", None), kc, vc, ksc, vsc


def cross_block_full(lp: dict, x: jax.Array, ctx: jax.Array,
                     cfg: ModelConfig
                     ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Gated cross-attention block (llama-3.2-vision style).

    Returns (x, ck, cv) — the projected context cache for decode reuse.
    """
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    ck, cv = attn.context_kv(lp["attn"], ctx, cfg)
    a = attn.cross_attn_full(lp["attn"], h, (ck, cv), cfg)
    x = x + jnp.tanh(lp["gate_attn"].astype(jnp.float32)).astype(x.dtype) * a
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    m = mlp_apply(lp["mlp"], h, cfg.mlp)
    x = x + jnp.tanh(lp["gate_mlp"].astype(jnp.float32)).astype(x.dtype) * m
    return x, ck, cv


def cross_block_decode(lp: dict, x: jax.Array, ck: jax.Array, cv: jax.Array,
                       cfg: ModelConfig) -> jax.Array:
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    a = attn.cross_attn_decode(lp["attn"], h, ck, cv, cfg)
    x = x + jnp.tanh(lp["gate_attn"].astype(jnp.float32)).astype(x.dtype) * a
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    m = mlp_apply(lp["mlp"], h, cfg.mlp)
    return x + jnp.tanh(lp["gate_mlp"].astype(jnp.float32)).astype(x.dtype) * m


# --------------------------------------------------------------------------
# Embedding / head
# --------------------------------------------------------------------------


def embed_tokens(params: dict, tokens: jax.Array, cfg: ModelConfig
                 ) -> jax.Array:
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return named(x, "batch", "seq", None)


def lm_head(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    w = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = (x @ w).astype(jnp.float32)
    return named(logits, "batch", "seq", "vocab")


# --------------------------------------------------------------------------
# Layer-pattern helpers
# --------------------------------------------------------------------------


def _layer_flags(cfg: ModelConfig) -> tuple[jax.Array, jax.Array]:
    """(is_global (L,), global_slot (L,)) for local:global interleaves."""
    flags = [cfg.is_global_layer(i) for i in range(cfg.n_layers)]
    slots, c = [], 0
    for f in flags:
        slots.append(c)
        c += int(f)
    return jnp.asarray(flags), jnp.asarray(slots, jnp.int32)


def n_global_layers(cfg: ModelConfig) -> int:
    return sum(cfg.is_global_layer(i) for i in range(cfg.n_layers))


def _dual(cfg: ModelConfig) -> bool:
    return cfg.local_global_ratio > 0 and cfg.sliding_window is not None


def local_cache_len(cfg: ModelConfig, max_len: int) -> int:
    w = cfg.sliding_window
    return min(w, max_len) if w else max_len


# --------------------------------------------------------------------------
# Forward (training) — logits over the full sequence
# --------------------------------------------------------------------------


def forward(params: dict, tokens: jax.Array, cfg: ModelConfig, *,
            ctx: Optional[jax.Array] = None, remat: bool = False,
            train: bool = True) -> tuple[jax.Array, jax.Array]:
    """Returns (logits (B,S,V) fp32, moe aux loss)."""
    b, s = tokens.shape
    x = embed_tokens(params, tokens, cfg)
    positions = jnp.arange(s)

    def self_body(x, lp, flag):
        if _dual(cfg):
            def global_fn(args):
                lp_, x_ = args
                xo, _, _, aux = block_full(lp_, x_, cfg, positions=positions,
                                           window=None, train=train)
                return xo, aux

            def local_fn(args):
                lp_, x_ = args
                xo, _, _, aux = block_full(lp_, x_, cfg, positions=positions,
                                           window=cfg.sliding_window,
                                           train=train)
                return xo, aux

            x, aux = jax.lax.cond(flag, global_fn, local_fn, (lp, x))
        else:
            x, _, _, aux = block_full(lp, x, cfg, positions=positions,
                                      window=cfg.sliding_window, train=train)
        return x, aux

    if remat:
        self_body = jax.checkpoint(
            self_body, policy=jax.checkpoint_policies.nothing_saveable)

    flags, _ = _layer_flags(cfg)

    if cfg.family == "vlm":
        assert ctx is not None, "vlm forward needs context embeddings"
        every = cfg.cross_attn_every
        g = cfg.n_layers // every
        grouped = jax.tree_util.tree_map(
            lambda a: a.reshape(g, every, *a.shape[1:]), params["layers"])

        def group_body(carry, xs):
            x, aux = carry
            glp, clp = xs

            def inner(carry2, lp):
                x2, aux2 = carry2
                x2, a2 = self_body(x2, lp, jnp.asarray(True))
                return (x2, aux2 + a2), None

            (x, aux), _ = jax.lax.scan(inner, (x, aux), glp)
            x, _, _ = cross_block_full(clp, x, ctx, cfg)
            return (x, aux), None

        (x, aux), _ = jax.lax.scan(
            group_body, (x, jnp.zeros((), jnp.float32)),
            (grouped, params["cross_layers"]))
    else:
        def body(carry, xs):
            x, aux = carry
            lp, flag = xs
            x, a = self_body(x, lp, flag)
            return (x, aux + a), None

        (x, aux), _ = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), (params["layers"], flags))

    return lm_head(params, x, cfg), aux


# --------------------------------------------------------------------------
# Prefill — forward + emit decode caches
# --------------------------------------------------------------------------


def _windowed_cache(k: jax.Array, w: int, max_len: int) -> jax.Array:
    """Extract a rolled (B, C, K, Dh) cache from full-seq k (B, S, K, Dh)."""
    b, s, kv, dh = k.shape
    c = min(w, max_len)
    if s <= c:
        out = jnp.zeros((b, c, kv, dh), k.dtype)
        return jax.lax.dynamic_update_slice(out, k, (0, 0, 0, 0))
    last = jax.lax.dynamic_slice_in_dim(k, s - c, c, axis=1)
    # slot of position p is p % c; positions [s-c, s) -> roll by s % c.
    return jnp.roll(last, shift=s % c, axis=1)


def _full_cache(k: jax.Array, max_len: int) -> jax.Array:
    b, s, kv, dh = k.shape
    if s == max_len:
        return k
    out = jnp.zeros((b, max_len, kv, dh), k.dtype)
    return jax.lax.dynamic_update_slice(out, k, (0, 0, 0, 0))


def prefill(params: dict, tokens: jax.Array, cfg: ModelConfig, *,
            max_len: Optional[int] = None, ctx: Optional[jax.Array] = None,
            length: Optional[jax.Array] = None) -> tuple[jax.Array, dict]:
    """Run the prompt; returns (last-position logits (B,V), cache dict).

    ``length`` (traced scalar) enables *length-masked* prefill for bucketed
    padding: ``tokens`` may be right-padded beyond the true prompt length,
    logits are read at position ``length - 1`` and the cache position is set
    to ``length``.  Pad rows write garbage K/V beyond ``length``, but decode
    masks the cache at ``pos + 1`` and overwrites those rows token by token,
    so they are never attended.  Only full (non-windowed) caches support
    this: a rolled sliding-window cache folds pad rows into real ones.
    """
    b, s = tokens.shape
    max_len = max_len or s
    if length is not None and (cfg.family == "vlm"
                               or cfg.sliding_window is not None):
        raise NotImplementedError(
            "length-masked prefill requires full (non-windowed) caches")
    x = embed_tokens(params, tokens, cfg)
    positions = jnp.arange(s)
    flags, gslots = _layer_flags(cfg)
    dual = _dual(cfg)
    w = cfg.sliding_window

    if cfg.family == "vlm":
        assert ctx is not None
        every = cfg.cross_attn_every
        g = cfg.n_layers // every
        grouped = jax.tree_util.tree_map(
            lambda a: a.reshape(g, every, *a.shape[1:]), params["layers"])

        def group_body(x, xs):
            glp, clp = xs

            def inner(x2, lp):
                x2, k, v, _ = block_full(lp, x2, cfg, positions=positions,
                                         window=None, train=False)
                return x2, (_full_cache(k, max_len), _full_cache(v, max_len))

            x, (ks, vs) = jax.lax.scan(inner, x, glp)
            x, ck, cv = cross_block_full(clp, x, ctx, cfg)
            return x, (ks, vs, ck, cv)

        x, (ks, vs, cks, cvs) = jax.lax.scan(
            group_body, x, (grouped, params["cross_layers"]))
        lk = ks.reshape(cfg.n_layers, *ks.shape[2:])
        lv = vs.reshape(cfg.n_layers, *vs.shape[2:])
        cache = {"k": lk, "v": lv, "cross_k": cks, "cross_v": cvs,
                 "pos": jnp.full((), s, jnp.int32)}
        return lm_head(params, x[:, -1:, :], cfg)[:, 0], cache

    n_glob = n_global_layers(cfg) if dual else 0
    gk0 = jnp.zeros((max(n_glob, 1), b, max_len, cfg.n_kv_heads, cfg.dh),
                    jnp.bfloat16)

    def body(carry, xs):
        x, gk, gv = carry
        lp, flag, gslot = xs
        if dual:
            def global_fn(ops_in):
                x_, gk_, gv_ = ops_in
                xo, k, v, _ = block_full(lp, x_, cfg, positions=positions,
                                         window=None, train=False)
                gk_ = jax.lax.dynamic_update_slice(
                    gk_, _full_cache(k, max_len)[None].astype(gk_.dtype),
                    (gslot, 0, 0, 0, 0))
                gv_ = jax.lax.dynamic_update_slice(
                    gv_, _full_cache(v, max_len)[None].astype(gv_.dtype),
                    (gslot, 0, 0, 0, 0))
                return xo, k, v, gk_, gv_

            def local_fn(ops_in):
                x_, gk_, gv_ = ops_in
                xo, k, v, _ = block_full(lp, x_, cfg, positions=positions,
                                         window=w, train=False)
                return xo, k, v, gk_, gv_

            x, k, v, gk, gv = jax.lax.cond(flag, global_fn, local_fn,
                                           (x, gk, gv))
            lc = local_cache_len(cfg, max_len)
            ys = (_windowed_cache(k, lc, max_len),
                  _windowed_cache(v, lc, max_len))
        else:
            x, k, v, _ = block_full(lp, x, cfg, positions=positions,
                                    window=w, train=False)
            if w:
                ys = (_windowed_cache(k, w, max_len),
                      _windowed_cache(v, w, max_len))
            elif quant:
                k8, ksn = attn.kv_quantize(k)
                v8, vsn = attn.kv_quantize(v)
                ys = (_full_cache(k8, max_len), _full_cache(v8, max_len),
                      _full_cache(ksn, max_len), _full_cache(vsn, max_len))
            else:
                ys = (_full_cache(k, max_len), _full_cache(v, max_len))
        return (x, gk, gv), ys

    quant = attn.kv_int8_enabled(cfg)
    (x, gk, gv), ys = jax.lax.scan(
        body, (x, gk0, gk0), (params["layers"], flags, gslots))
    if quant:
        ks, vs, kss, vss = ys
        cache = {"k": ks, "v": vs, "k_scale": kss, "v_scale": vss,
                 "pos": jnp.full((), s, jnp.int32)}
    else:
        ks, vs = ys
        cache = {"k": ks, "v": vs, "pos": jnp.full((), s, jnp.int32)}
    if dual:
        cache["global_k"], cache["global_v"] = gk, gv
    if length is None:
        last = x[:, -1:, :]
    else:
        n = jnp.asarray(length, jnp.int32)
        last = jax.lax.dynamic_slice_in_dim(x, n - 1, 1, axis=1)
        cache["pos"] = jnp.asarray(n, jnp.int32)
    logits = lm_head(params, last, cfg)[:, 0]
    return logits, cache


# --------------------------------------------------------------------------
# Decode — one token against the cache
# --------------------------------------------------------------------------


def decode_step(params: dict, token: jax.Array, cache: dict,
                cfg: ModelConfig) -> tuple[jax.Array, dict]:
    """token: (B,) int32. Returns (logits (B,V), updated cache)."""
    b = token.shape[0]
    pos = cache["pos"]  # scalar absolute position of the new token
    x = embed_tokens(params, token[:, None], cfg)
    flags, gslots = _layer_flags(cfg)
    dual = _dual(cfg)
    w = cfg.sliding_window
    rolled = w is not None and cache["k"].shape[2] <= w

    if cfg.family == "vlm":
        every = cfg.cross_attn_every
        g = cfg.n_layers // every
        grouped = jax.tree_util.tree_map(
            lambda a: a.reshape(g, every, *a.shape[1:]), params["layers"])
        kg = cache["k"].reshape(g, every, *cache["k"].shape[1:])
        vg = cache["v"].reshape(g, every, *cache["v"].shape[1:])

        def group_body(x, xs):
            glp, clp, kge, vge, ck, cv = xs

            def inner(x2, lxs):
                lp, kc, vc = lxs
                x2, kc, vc = block_decode(lp, x2, kc, vc, pos, cfg,
                                          rolled=False, window=None)
                return x2, (kc, vc)

            x, (kc, vc) = jax.lax.scan(inner, x, (glp, kge, vge))
            x = cross_block_decode(clp, x, ck, cv, cfg)
            return x, (kc, vc)

        x, (kn, vn) = jax.lax.scan(
            group_body, x,
            (grouped, params["cross_layers"], kg, vg,
             cache["cross_k"], cache["cross_v"]))
        new_cache = dict(cache)
        new_cache["k"] = kn.reshape(cfg.n_layers, *kn.shape[2:])
        new_cache["v"] = vn.reshape(cfg.n_layers, *vn.shape[2:])
        new_cache["pos"] = pos + 1
        return lm_head(params, x, cfg)[:, 0], new_cache

    gk = cache.get("global_k", jnp.zeros((1,) + cache["k"].shape[1:],
                                         cache["k"].dtype))
    gv = cache.get("global_v", gk)

    if attn.kv_int8_enabled(cfg):
        def qbody(x, xs):
            lp, kc, vc, ksc, vsc = xs
            x, kc, vc, ksc, vsc = block_decode_quant(lp, x, kc, vc, ksc,
                                                     vsc, pos, cfg)
            return x, (kc, vc, ksc, vsc)

        x, (kn, vn, ksn, vsn) = jax.lax.scan(
            qbody, x, (params["layers"], cache["k"], cache["v"],
                       cache["k_scale"], cache["v_scale"]))
        new_cache = dict(cache, k=kn, v=vn, k_scale=ksn, v_scale=vsn,
                         pos=pos + 1)
        return lm_head(params, x, cfg)[:, 0], new_cache

    def body(carry, xs):
        x, gk, gv = carry
        lp, flag, gslot, kc, vc = xs
        if dual:
            def global_fn(ops_in):
                x_, gk_, gv_, kc_, vc_ = ops_in
                gkl = jax.lax.dynamic_index_in_dim(gk_, gslot, 0,
                                                   keepdims=False)
                gvl = jax.lax.dynamic_index_in_dim(gv_, gslot, 0,
                                                   keepdims=False)
                xo, gkl, gvl = block_decode(lp, x_, gkl, gvl, pos, cfg,
                                            rolled=False, window=None)
                gk_ = jax.lax.dynamic_update_slice(
                    gk_, gkl[None], (gslot, 0, 0, 0, 0))
                gv_ = jax.lax.dynamic_update_slice(
                    gv_, gvl[None], (gslot, 0, 0, 0, 0))
                return xo, gk_, gv_, kc_, vc_

            def local_fn(ops_in):
                x_, gk_, gv_, kc_, vc_ = ops_in
                xo, kc_, vc_ = block_decode(lp, x_, kc_, vc_, pos, cfg,
                                            rolled=True, window=w)
                return xo, gk_, gv_, kc_, vc_

            x, gk, gv, kc, vc = jax.lax.cond(flag, global_fn, local_fn,
                                             (x, gk, gv, kc, vc))
        else:
            x, kc, vc = block_decode(lp, x, kc, vc, pos, cfg,
                                     rolled=rolled, window=w)
        return (x, gk, gv), (kc, vc)

    (x, gk, gv), (kn, vn) = jax.lax.scan(
        body, (x, gk, gv), (params["layers"], flags, gslots,
                            cache["k"], cache["v"]))
    new_cache = dict(cache, k=kn, v=vn, pos=pos + 1)
    if dual:
        new_cache["global_k"], new_cache["global_v"] = gk, gv
    return lm_head(params, x, cfg)[:, 0], new_cache


# --------------------------------------------------------------------------
# Paged decode — one token against block-paged KV pools
# --------------------------------------------------------------------------


def supports_paged(cfg: ModelConfig) -> bool:
    """Paged decode covers the full-cache dense/MoE paths: every KV row is
    addressed by absolute position, so block tables substitute directly.
    Rolled sliding-window and dual local:global caches fold positions
    (slot = pos % W) and would alias rows across blocks."""
    return (cfg.family in ("dense", "moe")
            and cfg.sliding_window is None
            and cfg.local_global_ratio == 0)


def decode_step_paged(params: dict, token: jax.Array, cache: dict,
                      block_tables: jax.Array, pos: jax.Array,
                      cfg: ModelConfig,
                      active: Optional[jax.Array] = None
                      ) -> tuple[jax.Array, dict]:
    """One decode step against block-paged KV pools.

    token: (B,) int32; cache: {"k","v"} of (L, N, bs, K, Dh) physical
    blocks shared across the batch (+ int8 scale pools when KV-int8 is
    on); block_tables: (B, M) int32 mapping each sequence's logical block
    slots to physical blocks; pos: (B,) int32 absolute positions;
    ``active`` ((B,), optional) suppresses free slots' KV writes.  The
    caller owns block allocation and position bookkeeping — this step
    only writes one row per sequence and attends its table.  Returns
    (logits (B, V), updated cache).
    """
    if not supports_paged(cfg):
        raise NotImplementedError(
            f"paged decode requires a full-cache dense/moe config, "
            f"got {cfg.name} ({cfg.family})")
    x = embed_tokens(params, token[:, None], cfg)
    pos = jnp.asarray(pos, jnp.int32)
    block_tables = jnp.asarray(block_tables, jnp.int32)

    if attn.kv_int8_enabled(cfg):
        def qbody(x, xs):
            lp, kc, vc, ksc, vsc = xs
            x, kc, vc, ksc, vsc = block_decode_paged_quant(
                lp, x, kc, vc, ksc, vsc, block_tables, pos, cfg, active)
            return x, (kc, vc, ksc, vsc)

        x, (kn, vn, ksn, vsn) = jax.lax.scan(
            qbody, x, (params["layers"], cache["k"], cache["v"],
                       cache["k_scale"], cache["v_scale"]))
        new_cache = dict(cache, k=kn, v=vn, k_scale=ksn, v_scale=vsn)
        return lm_head(params, x, cfg)[:, 0], new_cache

    def body(x, xs):
        lp, kc, vc = xs
        x, kc, vc = block_decode_paged(lp, x, kc, vc, block_tables, pos,
                                       cfg, active)
        return x, (kc, vc)

    x, (kn, vn) = jax.lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"]))
    new_cache = dict(cache, k=kn, v=vn)
    return lm_head(params, x, cfg)[:, 0], new_cache


# --------------------------------------------------------------------------
# Fused decode — sample on device, never ship logits to the host
# --------------------------------------------------------------------------


def greedy_tokens(logits: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Greedy next tokens, clipped to the real vocab (padded-vocab argmax
    can land on a pad logit only through float ties; the clip keeps the
    device sampler bit-identical to the engine's old host-side path)."""
    from repro.kernels import ops
    return ops.greedy_sample(logits, cfg.vocab_size)


def sampled_tokens(logits: jax.Array, cfg: ModelConfig, key, sampling
                   ) -> jax.Array:
    """Shared fused sampler: greedy when no key/sampling config is given,
    otherwise ``ops.sample_tokens`` (temperature / top-k / top-p) with the
    provided key.  Every family's fused token step — transformer, rwkv6,
    hybrid, encdec — funnels through here so the one-sync guarantee and
    the key-stream discipline are identical across families."""
    from repro.kernels import ops
    if key is None or sampling is None:
        return greedy_tokens(logits, cfg)
    return ops.sample_tokens(logits, key, cfg.vocab_size,
                             temperature=sampling.temperature,
                             top_k=sampling.top_k, top_p=sampling.top_p)


def decode_step_tokens(params: dict, token: jax.Array, cache: dict,
                       cfg: ModelConfig, key=None, sampling=None):
    """``decode_step`` with the sampler fused in: returns
    ``((B,) int32 next tokens, updated cache)`` — the serving engine's
    sync-free hot path pulls B int32s per round instead of (B, V) logits.
    With a PRNG ``key`` (threaded and donated exactly like the token
    vector) the step splits it in-jit, samples stochastically, and
    additionally returns the advanced key.
    """
    logits, cache = decode_step(params, token, cache, cfg)
    if key is None:
        return greedy_tokens(logits, cfg), cache
    key, sub = jax.random.split(key)
    return sampled_tokens(logits, cfg, sub, sampling), cache, key


def decode_step_paged_tokens(params: dict, token: jax.Array, cache: dict,
                             block_tables: jax.Array, pos: jax.Array,
                             active: jax.Array, cfg: ModelConfig,
                             key=None, sampling=None):
    """Fused paged round: sample on device AND advance the per-slot
    position vector in-jit (``pos + active``), so the engine keeps
    ``pos`` device-resident and only uploads it when admission, release,
    or migration touched the host mirror.  Free slots (``active == 0``)
    neither write KV nor advance.  Returns (tokens, cache, new pos), plus
    the advanced PRNG key when one is threaded through.
    """
    active = jnp.asarray(active, jnp.int32)
    logits, cache = decode_step_paged(params, token, cache, block_tables,
                                      pos, cfg, active=active)
    if key is None:
        return greedy_tokens(logits, cfg), cache, pos + active
    key, sub = jax.random.split(key)
    return (sampled_tokens(logits, cfg, sub, sampling), cache,
            pos + active, key)


# --------------------------------------------------------------------------
# Speculative verify — score a k+1 window in one forward
# --------------------------------------------------------------------------


def block_verify(lp: dict, x: jax.Array, kc: jax.Array, vc: jax.Array,
                 pos: jax.Array, cfg: ModelConfig
                 ) -> tuple[jax.Array, jax.Array, jax.Array]:
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    a, kc, vc = attn.attn_verify(lp["attn"], h, kc, vc, pos, cfg)
    x = x + a
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    m, _ = _ffn(lp, h, cfg, train=False)
    return x + m, kc, vc


def block_verify_paged(lp: dict, x: jax.Array, kc: jax.Array, vc: jax.Array,
                       block_tables: jax.Array, pos: jax.Array,
                       cfg: ModelConfig,
                       active: Optional[jax.Array] = None
                       ) -> tuple[jax.Array, jax.Array, jax.Array]:
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    a, kc, vc = attn.attn_verify_paged(lp["attn"], h, kc, vc,
                                       block_tables, pos, cfg, active)
    x = x + a
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    m, _ = _ffn(lp, h, cfg, train=False)
    return x + m, kc, vc


def supports_speculative(cfg: ModelConfig) -> bool:
    """The verify step addresses KV rows by absolute position (like the
    paged plane) and writes a W-row window per round, so it covers the
    same full-cache dense/MoE configs — minus the int8 KV variant, whose
    per-row scale pools would need a windowed quantized writer."""
    return supports_paged(cfg) and not attn.kv_int8_enabled(cfg)


def verify_step(params: dict, tokens: jax.Array, cache: dict,
                cfg: ModelConfig) -> tuple[jax.Array, dict]:
    """Score a speculative window in one forward against a dense cache.

    tokens: (B, W) int32 — [last emitted token, k draft tokens], W=k+1.
    Writes the window's KV rows at cache["pos"]..pos+W-1 and returns
    (logits (B, W, V), updated cache); ``logits[:, j]`` is the target
    distribution for the token *after* window position j.  ``cache["pos"]``
    is left untouched — the caller folds the accepted-prefix length in
    (the rejected rows beyond the new position are garbage the causal
    mask hides until they are overwritten, exactly like bucketed
    prefill's padded tail).
    """
    if not supports_speculative(cfg):
        raise NotImplementedError(
            f"speculative verify requires a full-cache dense/moe config, "
            f"got {cfg.name} ({cfg.family})")
    b = tokens.shape[0]
    pos = jnp.broadcast_to(jnp.atleast_1d(jnp.asarray(cache["pos"])),
                           (b,)).astype(jnp.int32)
    x = embed_tokens(params, tokens, cfg)

    def body(x, xs):
        lp, kc, vc = xs
        x, kc, vc = block_verify(lp, x, kc, vc, pos, cfg)
        return x, (kc, vc)

    x, (kn, vn) = jax.lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"]))
    return lm_head(params, x, cfg), dict(cache, k=kn, v=vn)


def verify_step_paged(params: dict, tokens: jax.Array, cache: dict,
                      block_tables: jax.Array, pos: jax.Array,
                      cfg: ModelConfig,
                      active: Optional[jax.Array] = None
                      ) -> tuple[jax.Array, dict]:
    """``verify_step`` against block-paged KV pools: writes the window's
    rows through the per-position paged scatter (inactive slots drop) and
    returns (logits (B, W, V), updated cache).  Position bookkeeping
    stays with the caller."""
    if not supports_speculative(cfg):
        raise NotImplementedError(
            f"speculative verify requires a full-cache dense/moe config, "
            f"got {cfg.name} ({cfg.family})")
    x = embed_tokens(params, tokens, cfg)
    pos = jnp.asarray(pos, jnp.int32)
    block_tables = jnp.asarray(block_tables, jnp.int32)

    def body(x, xs):
        lp, kc, vc = xs
        x, kc, vc = block_verify_paged(lp, x, kc, vc, block_tables, pos,
                                       cfg, active)
        return x, (kc, vc)

    x, (kn, vn) = jax.lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"]))
    return lm_head(params, x, cfg), dict(cache, k=kn, v=vn)
