from repro.models.config import ModelConfig
from repro.models.model import (SHAPE_CASES, Model, ShapeCase, build_model,
                                input_specs)

__all__ = ["ModelConfig", "Model", "build_model", "input_specs",
           "ShapeCase", "SHAPE_CASES"]
