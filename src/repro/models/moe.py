"""Mixture-of-Experts block: top-k routing with sort-based dispatch.

Design (DESIGN.md §5): expert weights are *not* sharded over an expert axis;
each expert's matrices shard 2D over (fsdp=data, tp=model) like a dense MLP.
Routing is therefore all-to-all-free: tokens are sorted by expert id,
gathered into per-expert capacity buckets, pushed through a batched
(E, C, D) x (E, D, F) einsum, and combined back with their gate weights.
Overflow beyond capacity is dropped (standard capacity-factor semantics);
the router's load-balancing auxiliary loss keeps drops rare in training.

The baseline lowers under auto-SPMD (XLA inserts the collectives around the
global argsort); the §Perf hillclimb replaces this with shard_map-local
routing and measures the difference.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
try:  # jax >= 0.8 promotes shard_map out of experimental
    from jax import shard_map
except ImportError:  # pragma: no cover - older jax
    from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import current_mesh, named
from repro.models.config import ModelConfig
from repro.models.layers import PSpec


def moe_specs(cfg: ModelConfig) -> dict[str, PSpec]:
    d, fe, e = cfg.d_model, cfg.expert_d_ff, cfg.n_experts
    s = {
        "router": PSpec((d, e), ("fsdp", None), dtype=jnp.float32),
        "w_gate": PSpec((e, d, fe), (None, "fsdp", "tp")),
        "w_up": PSpec((e, d, fe), (None, "fsdp", "tp")),
        "w_down": PSpec((e, fe, d), (None, "tp", "fsdp")),
    }
    if cfg.n_shared_experts:
        fs = cfg.n_shared_experts * fe
        s["shared"] = {
            "w_gate": PSpec((d, fs), ("fsdp", "tp")),
            "w_up": PSpec((d, fs), ("fsdp", "tp")),
            "w_down": PSpec((fs, d), ("tp", "fsdp")),
            "gate": PSpec((d, 1), ("fsdp", None)),
        }
    return s


def _capacity(n_tokens: int, cfg: ModelConfig, factor: float) -> int:
    c = int(n_tokens * cfg.top_k * factor / cfg.n_experts) + 1
    # One expert can receive at most one pair per token (top-k experts are
    # distinct), so capacity beyond n_tokens is dead rows.  Clamping is
    # lossless and matters on the decode hot path: a B-slot decode round
    # has n_tokens == B, and without the clamp every expert bucket pads to
    # the training floor of 8 — 2-4x wasted expert-FFN FLOPs per round.
    return min(max(c, cfg.top_k, 8), max(n_tokens, 1))


@dataclasses.dataclass
class MoEStats:
    aux_loss: jax.Array  # load-balancing loss (Switch-style)


def moe_apply(params: dict, x: jax.Array, cfg: ModelConfig,
              capacity_factor: float = 1.25
              ) -> tuple[jax.Array, jax.Array]:
    """Returns (output (B,S,D), aux_loss scalar).

    With an active mesh this dispatches to the shard_map-local path
    (§Perf iteration B1): tokens are routed entirely within their data
    shard — no global argsort/scatter collectives — and the only wire
    traffic left is the per-layer FSDP weight gather plus one TP psum of
    the combined output, exactly like a dense MLP.
    """
    import os
    mesh = current_mesh()
    if (mesh is not None and "model" in mesh.shape
            and os.environ.get("REPRO_BASELINE", "") != "1"):
        dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
        b = x.shape[0]
        import math as _math
        if dp and b % _math.prod(mesh.shape[a] for a in dp) == 0:
            return _moe_apply_shardmap(params, x, cfg, capacity_factor,
                                       mesh, dp)
    return _moe_apply_global(params, x, cfg, capacity_factor)


def _moe_local(router, w_gate, w_up, w_down, shared, xt, cfg: ModelConfig,
               cap: int) -> tuple[jax.Array, jax.Array]:
    """Route + compute experts for the local token slab ``xt`` (T, D).

    Expert FFN dims may be TP shards; the caller psums the partial output.
    """
    t, d = xt.shape
    e, k = cfg.n_experts, cfg.top_k

    logits = (xt.astype(jnp.float32) @ router)  # (T, E)
    gates = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(gates, k)  # (T, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    me = gates.mean(axis=0)
    ce = jnp.zeros((e,)).at[top_e.reshape(-1)].add(1.0) / (t * k)
    aux = e * jnp.sum(me * ce)

    pair_e = top_e.reshape(-1)
    pair_tok = jnp.repeat(jnp.arange(t), k)
    pair_w = top_w.reshape(-1)
    order = jnp.argsort(pair_e, stable=True)
    pe, ptok, pw = pair_e[order], pair_tok[order], pair_w[order]
    counts = jnp.zeros((e,), jnp.int32).at[pe].add(1)
    offsets = jnp.cumsum(counts) - counts
    within = jnp.arange(t * k) - offsets[pe]
    keep = within < cap
    dest = jnp.where(keep, pe * cap + within, e * cap)

    buckets = jnp.zeros((e * cap + 1, d), xt.dtype).at[dest].set(xt[ptok])
    expert_in = buckets[:-1].reshape(e, cap, d)

    h_gate = jnp.einsum("ecd,edf->ecf", expert_in, w_gate)
    h_up = jnp.einsum("ecd,edf->ecf", expert_in, w_up)
    h = jax.nn.silu(h_gate.astype(jnp.float32)).astype(xt.dtype) * h_up
    expert_out = jnp.einsum("ecf,efd->ecd", h, w_down)

    flat = jnp.concatenate(
        [expert_out.reshape(e * cap, d),
         jnp.zeros((1, d), expert_out.dtype)], axis=0)
    pair_out = flat[dest] * (pw * keep).astype(xt.dtype)[:, None]
    y = jnp.zeros((t, d), xt.dtype).at[ptok].add(pair_out)

    if shared is not None:
        sw_gate, sw_up, sw_down, sgate = shared
        g = jax.nn.silu((xt @ sw_gate).astype(jnp.float32)).astype(xt.dtype)
        hs = g * (xt @ sw_up)
        shared_out = hs @ sw_down
        mix = jax.nn.sigmoid((xt.astype(jnp.float32) @ sgate))
        y = y + shared_out * mix.astype(xt.dtype)
    return y, aux


def _moe_apply_shardmap(params: dict, x: jax.Array, cfg: ModelConfig,
                        capacity_factor: float, mesh, dp: tuple
                        ) -> tuple[jax.Array, jax.Array]:
    """shard_map-local routing: data-parallel token slabs, TP expert FFNs."""
    import math as _math
    b, s, d = x.shape
    n_dp = _math.prod(mesh.shape[a] for a in dp)
    t_local = (b // n_dp) * s
    cap = _capacity(t_local, cfg, capacity_factor)
    has_shared = "shared" in params
    dp_spec = dp if len(dp) > 1 else dp[0]

    def local_fn(xl, router, w_gate, w_up, w_down, *shared_args):
        xt = xl.reshape(-1, d)
        shared = shared_args if has_shared else None
        y, aux = _moe_local(router, w_gate, w_up, w_down, shared, xt, cfg,
                            cap)
        # Expert/shared FFN dims are TP shards -> partial sums; one psum
        # combines routed + shared contributions (the dense-MLP pattern).
        y = jax.lax.psum(y, "model")
        aux = jax.lax.pmean(aux, dp) if dp else aux
        return y.reshape(xl.shape), aux

    in_specs = [P(dp_spec, None, None), P(None, None),
                P(None, None, "model"), P(None, None, "model"),
                P(None, "model", None)]
    args = [x, params["router"], params["w_gate"], params["w_up"],
            params["w_down"]]
    if has_shared:
        sp = params["shared"]
        args += [sp["w_gate"], sp["w_up"], sp["w_down"], sp["gate"]]
        in_specs += [P(None, "model"), P(None, "model"), P("model", None),
                     P(None, None)]
    fn = shard_map(local_fn, mesh=mesh, in_specs=tuple(in_specs),
                   out_specs=(P(dp_spec, None, None), P()),
                   check_rep=False)
    return fn(*args)


def _moe_apply_global(params: dict, x: jax.Array, cfg: ModelConfig,
                      capacity_factor: float = 1.25
                      ) -> tuple[jax.Array, jax.Array]:
    """Single-device / auto-SPMD reference path (the pre-B1 baseline)."""
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    cap = _capacity(t, cfg, capacity_factor)
    xt = x.reshape(t, d)

    logits = (xt.astype(jnp.float32) @ params["router"])  # (T, E)
    gates = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(gates, k)  # (T, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # Switch-transformer load-balancing aux loss.
    me = gates.mean(axis=0)  # (E,)
    ce = jnp.zeros((e,)).at[top_e.reshape(-1)].add(1.0) / (t * k)
    aux = e * jnp.sum(me * ce)

    # ---- dispatch: sort token-expert pairs by expert ----------------------
    pair_e = top_e.reshape(-1)  # (T*k,)
    pair_tok = jnp.repeat(jnp.arange(t), k)
    pair_w = top_w.reshape(-1)
    order = jnp.argsort(pair_e, stable=True)
    pe, ptok, pw = pair_e[order], pair_tok[order], pair_w[order]
    counts = jnp.zeros((e,), jnp.int32).at[pe].add(1)
    offsets = jnp.cumsum(counts) - counts  # start index per expert
    within = jnp.arange(t * k) - offsets[pe]
    keep = within < cap
    dest = jnp.where(keep, pe * cap + within, e * cap)  # overflow -> trash row

    buckets = jnp.zeros((e * cap + 1, d), x.dtype).at[dest].set(xt[ptok])
    expert_in = buckets[:-1].reshape(e, cap, d)

    # ---- per-expert gated FFN (batched over experts) ----------------------
    h_gate = jnp.einsum("ecd,edf->ecf", expert_in, params["w_gate"])
    h_up = jnp.einsum("ecd,edf->ecf", expert_in, params["w_up"])
    h = jax.nn.silu(h_gate.astype(jnp.float32)).astype(x.dtype) * h_up
    h = named(h, None, None, "d_ff")
    expert_out = jnp.einsum("ecf,efd->ecd", h, params["w_down"])

    # ---- combine ------------------------------------------------------------
    flat = jnp.concatenate(
        [expert_out.reshape(e * cap, d),
         jnp.zeros((1, d), expert_out.dtype)], axis=0)
    pair_out = flat[dest] * (pw * keep).astype(x.dtype)[:, None]
    y = jnp.zeros((t, d), x.dtype).at[ptok].add(pair_out)

    if "shared" in params:
        sp = params["shared"]
        g = jax.nn.silu((xt @ sp["w_gate"]).astype(jnp.float32)).astype(x.dtype)
        hs = g * (xt @ sp["w_up"])
        shared_out = hs @ sp["w_down"]
        mix = jax.nn.sigmoid((xt.astype(jnp.float32) @ sp["gate"]))
        y = y + shared_out * mix.astype(x.dtype)

    return y.reshape(b, s, d), aux
