"""Hymba-style hybrid: parallel attention + SSM heads per layer (hymba-1.5b).

Each layer normalizes the residual stream once, runs a GQA attention path
and a Mamba-style selective-scan path *in parallel on the same input*, mean-
fuses the per-path outputs after per-path RMS normalization (the Hymba
fusion), then a gated MLP.  Learnable *meta tokens* are prepended to the
sequence (and live at the start of the decode cache).

Attention is sliding-window (cfg.sliding_window) — with the O(1) SSM state
this keeps the long_500k cache bounded, per Hymba's global/local design
(simplification recorded in DESIGN.md: all attention layers are windowed
here, Hymba keeps 3 global layers).
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import named
from repro.kernels import ops
from repro.models import attention as attn
from repro.models.config import ModelConfig
from repro.models.layers import (PSpec, mlp_apply, mlp_specs, rms_norm,
                                 stack_tree)
from repro.models.transformer import _full_cache, _windowed_cache, lm_head


def ssm_specs(cfg: ModelConfig) -> dict[str, PSpec]:
    d, n = cfg.d_model, cfg.ssm_state
    h, dh = cfg.n_heads, cfg.dh
    return {
        "w_in": PSpec((d, h * dh), ("fsdp", "tp")),
        "w_dt": PSpec((d, h), ("fsdp", None)),
        "dt_bias": PSpec((h,), (None,), init="small"),
        "a_log": PSpec((h, n), (None, None), init="small"),
        "w_b": PSpec((d, h * n), ("fsdp", None)),
        "w_c": PSpec((d, h * n), ("fsdp", None)),
        "w_out": PSpec((h * dh, d), ("tp", "fsdp")),
    }


def block_specs(cfg: ModelConfig) -> dict[str, Any]:
    d = cfg.d_model
    return {
        "ln1": PSpec((d,), (None,), init="zeros"),
        "attn": attn.attn_specs(cfg),
        "ln_attn": PSpec((d,), (None,), init="zeros"),
        "ssm": ssm_specs(cfg),
        "ln_ssm": PSpec((d,), (None,), init="zeros"),
        "ln2": PSpec((d,), (None,), init="zeros"),
        "mlp": mlp_specs(d, cfg.d_ff, cfg.mlp),
    }


def hybrid_specs(cfg: ModelConfig) -> dict[str, Any]:
    d, v = cfg.d_model, cfg.padded_vocab
    return {
        "embed": PSpec((v, d), ("vocab", "fsdp"), init="small"),
        "meta": PSpec((cfg.n_context_tokens or 128, d), (None, None),
                      init="small"),
        "layers": stack_tree(block_specs(cfg), cfg.n_layers),
        "ln_f": PSpec((d,), (None,), init="zeros"),
        "head": PSpec((d, v), ("fsdp", "vocab")),
    }


def _ssm_path(p: dict, x: jax.Array, state: jax.Array, cfg: ModelConfig
              ) -> tuple[jax.Array, jax.Array]:
    b, s, d = x.shape
    h, dh, n = cfg.n_heads, cfg.dh, cfg.ssm_state
    xin = (x @ p["w_in"]).reshape(b, s, h, dh)
    xin = named(xin, "batch", "seq", "heads", None)
    dt = jax.nn.softplus((x @ p["w_dt"]).astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    bmat = (x @ p["w_b"]).reshape(b, s, h, n)
    cmat = (x @ p["w_c"]).reshape(b, s, h, n)
    y, state = ops.ssm_scan(xin, dt.astype(x.dtype), p["a_log"], bmat, cmat,
                            state)
    y = named(y, "batch", "seq", "heads", None)
    out = y.reshape(b, s, h * dh) @ p["w_out"]
    return named(out, "batch", "seq", None), state


def _fuse(lp: dict, a: jax.Array, m: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Hymba mean fusion of per-path normalized outputs."""
    return 0.5 * (rms_norm(a, lp["ln_attn"], cfg.norm_eps)
                  + rms_norm(m, lp["ln_ssm"], cfg.norm_eps))


def _block_full(lp, x, state0, cfg, positions):
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    a, k, v = attn.attn_full(lp["attn"], h, cfg, positions=positions,
                             window=cfg.sliding_window)
    m, state = _ssm_path(lp["ssm"], h, state0, cfg)
    x = x + _fuse(lp, a, m, cfg)
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    x = named(x + mlp_apply(lp["mlp"], h, cfg.mlp), "batch", "seq", None)
    return x, k, v, state


def forward(params: dict, tokens: jax.Array, cfg: ModelConfig, *,
            ctx=None, remat: bool = False,
            train: bool = True) -> tuple[jax.Array, jax.Array]:
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    meta = jnp.broadcast_to(params["meta"][None], (b, *params["meta"].shape))
    x = jnp.concatenate([meta.astype(x.dtype), x], axis=1)
    x = named(x, "batch", "seq", None)
    positions = jnp.arange(x.shape[1])
    state0 = jnp.zeros((b, cfg.n_heads, cfg.dh, cfg.ssm_state), jnp.float32)

    def body(x, lp):
        x, _, _, _ = _block_full(lp, x, state0, cfg, positions)
        return x, None

    if remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["layers"])
    n_meta = params["meta"].shape[0]
    logits = lm_head(params, x[:, n_meta:], cfg)
    return logits, jnp.zeros((), jnp.float32)


def prefill(params: dict, tokens: jax.Array, cfg: ModelConfig, *,
            max_len: Optional[int] = None, ctx=None
            ) -> tuple[jax.Array, dict]:
    b, s = tokens.shape
    n_meta = params["meta"].shape[0]
    max_len = (max_len or s) + n_meta
    x = jnp.take(params["embed"], tokens, axis=0)
    meta = jnp.broadcast_to(params["meta"][None], (b, *params["meta"].shape))
    x = jnp.concatenate([meta.astype(x.dtype), x], axis=1)
    positions = jnp.arange(x.shape[1])
    state0 = jnp.zeros((b, cfg.n_heads, cfg.dh, cfg.ssm_state), jnp.float32)
    w = cfg.sliding_window

    def body(x, lp):
        x, k, v, state = _block_full(lp, x, state0, cfg, positions)
        if w:
            ys = (_windowed_cache(k, w, max_len),
                  _windowed_cache(v, w, max_len), state)
        else:
            ys = (_full_cache(k, max_len), _full_cache(v, max_len), state)
        return x, ys

    x, (ks, vs, states) = jax.lax.scan(body, x, params["layers"])
    logits = lm_head(params, x[:, -1:, :], cfg)[:, 0]
    cache = {"k": ks, "v": vs, "ssm": states,
             "pos": jnp.full((), s + n_meta, jnp.int32)}
    return logits, cache


def decode_step(params: dict, token: jax.Array, cache: dict,
                cfg: ModelConfig) -> tuple[jax.Array, dict]:
    pos = cache["pos"]
    x = jnp.take(params["embed"], token[:, None], axis=0)
    w = cfg.sliding_window
    rolled = w is not None and cache["k"].shape[2] <= w
    positions = None  # attn_decode derives positions from pos

    def body(x, xs):
        lp, kc, vc, state = xs
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        a, kc, vc = attn.attn_decode(lp["attn"], h, kc, vc, pos, cfg,
                                     rolled=rolled, window=w)
        m, state = _ssm_path(lp["ssm"], h, state, cfg)
        x = x + _fuse(lp, a, m, cfg)
        h2 = rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + mlp_apply(lp["mlp"], h2, cfg.mlp)
        return x, (kc, vc, state)

    x, (kn, vn, sn) = jax.lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"], cache["ssm"]))
    logits = lm_head(params, x, cfg)[:, 0]
    return logits, {"k": kn, "v": vn, "ssm": sn, "pos": pos + 1}
