"""Model registry: family dispatch, cache factories, dry-run input specs."""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import encdec, hybrid, rwkv6, transformer
from repro.models.config import ModelConfig
from repro.models.layers import (abstract_params, init_params,
                                 param_count, param_logical_names)

_FAMILY = {
    "dense": transformer,
    "moe": transformer,
    "vlm": transformer,
    "encdec": encdec,
    "rwkv": rwkv6,
    "hybrid": hybrid,
}


def _specs_for(cfg: ModelConfig) -> Any:
    if cfg.family in ("dense", "moe", "vlm"):
        return transformer.decoder_specs(cfg)
    if cfg.family == "encdec":
        return encdec.encdec_specs(cfg)
    if cfg.family == "rwkv":
        return rwkv6.rwkv_specs(cfg)
    if cfg.family == "hybrid":
        return hybrid.hybrid_specs(cfg)
    raise ValueError(cfg.family)


def default_kv_blocks(max_batch: int, max_len: int, block_size: int) -> int:
    """Default pool: the dense slot pool's TOTAL block count (one of which
    becomes the null page), so the default paged admission charge never
    exceeds the dense reservation.  The null page costs one usable block
    only when every slot runs a full-``max_len`` request concurrently:
    with ``max_batch >= 2`` the head-of-line request waits a round; at
    ``max_batch == 1`` a full-``max_len`` request exceeds the pool and is
    rejected at submit — pass an explicit ``n_kv_blocks`` one larger to
    serve it.  Minimum 2 (the null page plus one usable block)."""
    return max(max_batch * (-(-max_len // block_size)), 2)


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    @functools.cached_property
    def specs(self) -> Any:
        return _specs_for(self.cfg)

    # -- params -----------------------------------------------------------

    def init(self, key: jax.Array) -> Any:
        return init_params(self.specs, key)

    def abstract_params(self) -> Any:
        return abstract_params(self.specs)

    def param_names(self) -> Any:
        return param_logical_names(self.specs)

    def n_params(self) -> int:
        return param_count(self.specs)

    # -- steps ---------------------------------------------------------------

    def forward(self, params, tokens, *, ctx=None, remat=False,
                train=True):
        return _FAMILY[self.cfg.family].forward(params, tokens, self.cfg,
                                                ctx=ctx, remat=remat,
                                                train=train)

    def prefill(self, params, tokens, *, max_len=None, ctx=None, length=None):
        kw = {} if length is None else {"length": length}
        return _FAMILY[self.cfg.family].prefill(params, tokens, self.cfg,
                                                max_len=max_len, ctx=ctx,
                                                **kw)

    def supports_bucketed_prefill(self) -> bool:
        """Whether ``prefill(..., length=n)`` can consume right-padded
        prompts (full per-position caches only; see transformer.prefill)."""
        return (self.cfg.family in ("dense", "moe")
                and self.cfg.sliding_window is None)

    def decode_step(self, params, token, cache):
        return _FAMILY[self.cfg.family].decode_step(params, token, cache,
                                                    self.cfg)

    # -- fused decode (sync-free hot path) ----------------------------------

    def sample_greedy(self, logits):
        """Device-side greedy sampler (argmax + vocab clip), shared by the
        fused decode steps and the engine's prefill admission path."""
        return transformer.greedy_tokens(logits, self.cfg)

    def sample_tokens(self, logits, key, sampling):
        """Device-side sampler with a PRNG key: greedy when ``sampling`` is
        ``None``, else temperature/top-k/top-p via ``ops.sample_tokens``."""
        return transformer.sampled_tokens(logits, self.cfg, key, sampling)

    def decode_step_tokens(self, params, token, cache, key=None,
                           sampling=None):
        """One decode round returning ``((B,) int32 tokens, cache)`` — the
        logits never leave the device (any family).  With a PRNG ``key``
        the round splits it in-jit, routes the logits through the shared
        fused sampler (``transformer.sampled_tokens``), and returns the
        advanced key as a third element; the rwkv6/hybrid/encdec families
        take the same split-then-sample path so their fused rounds keep
        the one-sync guarantee under stochastic sampling too."""
        if self.cfg.family in ("dense", "moe", "vlm"):
            return transformer.decode_step_tokens(params, token, cache,
                                                  self.cfg, key=key,
                                                  sampling=sampling)
        logits, cache = self.decode_step(params, token, cache)
        if key is None:
            return transformer.greedy_tokens(logits, self.cfg), cache
        key, sub = jax.random.split(key)
        return (transformer.sampled_tokens(logits, self.cfg, sub, sampling),
                cache, key)

    def decode_step_paged_tokens(self, params, token, cache, block_tables,
                                 pos, active, key=None, sampling=None):
        """Fused paged round: ``(tokens, cache, pos + active)`` with free
        slots' writes suppressed (see transformer.decode_step_paged_tokens);
        a threaded PRNG key adds stochastic sampling and a returned key.
        """
        return transformer.decode_step_paged_tokens(
            params, token, cache, block_tables, pos, active, self.cfg,
            key=key, sampling=sampling)

    # -- speculative verify --------------------------------------------------

    def supports_speculative(self) -> bool:
        """Whether the batched verify step covers this config (full-cache
        dense/MoE, no int8 KV)."""
        return (self.cfg.family in ("dense", "moe")
                and transformer.supports_speculative(self.cfg))

    def verify_step(self, params, tokens, cache):
        """Score a (B, W) speculative window in one forward against the
        dense slot cache: ``(logits (B, W, V), cache)``, positions
        untouched (see transformer.verify_step)."""
        if not self.supports_speculative():
            raise NotImplementedError(
                f"speculative verify unsupported for {self.cfg.name}")
        return transformer.verify_step(params, tokens, cache, self.cfg)

    def verify_step_paged(self, params, tokens, cache, block_tables, pos,
                          active=None):
        """Paged-window variant of ``verify_step``."""
        if not self.supports_speculative():
            raise NotImplementedError(
                f"speculative verify unsupported for {self.cfg.name}")
        return transformer.verify_step_paged(params, tokens, cache,
                                             block_tables, pos, self.cfg,
                                             active)

    # -- caches ------------------------------------------------------------------

    def cache_shapes(self, batch: int, max_len: int
                     ) -> dict[str, jax.ShapeDtypeStruct]:
        """Abstract decode-cache tree for a cache holding ``max_len`` tokens."""
        cfg = self.cfg
        l, kv, dh = cfg.n_layers, cfg.n_kv_heads, cfg.dh
        f32, bf16, i32 = jnp.float32, jnp.bfloat16, jnp.int32
        sds = jax.ShapeDtypeStruct
        if cfg.family == "rwkv":
            h, rdh = cfg.d_model // 64, 64
            return {
                "wkv": sds((l, batch, h, rdh, rdh), f32),
                "tm_x": sds((l, batch, cfg.d_model), bf16),
                "cm_x": sds((l, batch, cfg.d_model), bf16),
                "pos": sds((), i32),
            }
        if cfg.family == "hybrid":
            n_meta = cfg.n_context_tokens or 128
            c = transformer.local_cache_len(cfg, max_len + n_meta)
            return {
                "k": sds((l, batch, c, kv, dh), bf16),
                "v": sds((l, batch, c, kv, dh), bf16),
                "ssm": sds((l, batch, cfg.n_heads, dh, cfg.ssm_state), f32),
                "pos": sds((), i32),
            }
        if cfg.family == "encdec":
            ctx_len = cfg.n_context_tokens
            return {
                "k": sds((l, batch, max_len, kv, dh), bf16),
                "v": sds((l, batch, max_len, kv, dh), bf16),
                "cross_k": sds((l, batch, ctx_len, kv, dh), bf16),
                "cross_v": sds((l, batch, ctx_len, kv, dh), bf16),
                "pos": sds((), i32),
            }
        if cfg.family == "vlm":
            g = cfg.n_layers // cfg.cross_attn_every
            ctx_len = cfg.n_context_tokens
            return {
                "k": sds((l, batch, max_len, kv, dh), bf16),
                "v": sds((l, batch, max_len, kv, dh), bf16),
                "cross_k": sds((g, batch, ctx_len, kv, dh), bf16),
                "cross_v": sds((g, batch, ctx_len, kv, dh), bf16),
                "pos": sds((), i32),
            }
        # dense / moe
        from repro.models.attention import kv_int8_enabled
        c = transformer.local_cache_len(cfg, max_len)
        if kv_int8_enabled(cfg):
            return {
                "k": sds((l, batch, c, kv, dh), jnp.int8),
                "v": sds((l, batch, c, kv, dh), jnp.int8),
                "k_scale": sds((l, batch, c, kv, 1), bf16),
                "v_scale": sds((l, batch, c, kv, 1), bf16),
                "pos": sds((), i32),
            }
        tree = {
            "k": sds((l, batch, c, kv, dh), bf16),
            "v": sds((l, batch, c, kv, dh), bf16),
            "pos": sds((), i32),
        }
        if cfg.local_global_ratio > 0 and cfg.sliding_window:
            g = transformer.n_global_layers(cfg)
            tree["global_k"] = sds((g, batch, max_len, kv, dh), bf16)
            tree["global_v"] = sds((g, batch, max_len, kv, dh), bf16)
        return tree

    def cache_names(self, batch: int, max_len: int) -> dict[str, tuple]:
        """Logical dimension names matching cache_shapes (for shardings)."""
        kvnames = ("layers", "batch", "seq", "kv_heads", None)
        cfg = self.cfg
        if cfg.family == "rwkv":
            return {
                "wkv": ("layers", "batch", "heads", None, None),
                "tm_x": ("layers", "batch", None),
                "cm_x": ("layers", "batch", None),
                "pos": (),
            }
        names: dict[str, tuple] = {}
        for key in self.cache_shapes(batch, max_len):
            if key == "pos":
                names[key] = ()
            elif key == "ssm":
                names[key] = ("layers", "batch", "heads", None, None)
            else:
                names[key] = kvnames
        return names

    def init_cache(self, batch: int, max_len: int) -> Any:
        """Real zeroed cache (engine / smoke tests)."""
        return jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype),
            self.cache_shapes(batch, max_len))

    # -- slot caches (continuous batching) ---------------------------------

    def slot_batch_axes(self, max_len: int) -> dict[str, int]:
        """Index of the batch ('slot') axis for every cache leaf except
        ``pos``, which becomes per-slot (n_slots,) in a slot cache."""
        names = self.cache_names(1, max_len)
        return {k: v.index("batch") for k, v in names.items() if k != "pos"}

    def init_slot_cache(self, n_slots: int, max_len: int) -> Any:
        """Persistent decode-slot pool: ``init_cache`` with a *per-slot*
        position vector, so every slot tracks its own sequence length and
        finished slots can be re-filled mid-flight."""
        cache = self.init_cache(n_slots, max_len)
        cache["pos"] = jnp.zeros((n_slots,), jnp.int32)
        return cache

    def merge_slot(self, cache: Any, entry: Any, slot: jax.Array) -> Any:
        """Scatter a batch-1 prefill cache ``entry`` into slot ``slot``.

        ``cache`` is a slot pool from ``init_slot_cache``; ``entry`` a cache
        returned by ``prefill`` for a single request with the same
        ``max_len``.  jit-compatible: ``slot`` may be a traced scalar.
        """
        axes = self.slot_batch_axes(1)
        out = dict(cache)
        for key, leaf in cache.items():
            if key == "pos":
                out[key] = leaf.at[slot].set(
                    entry["pos"].astype(leaf.dtype))
            else:
                out[key] = jax.lax.dynamic_update_slice_in_dim(
                    leaf, entry[key].astype(leaf.dtype), slot,
                    axis=axes[key])
        return out

    def gather_slot(self, cache: Any, slot: jax.Array) -> Any:
        """Extract slot ``slot`` of a slot pool as a batch-1 cache (with a
        scalar ``pos``) — the exact inverse of ``merge_slot``."""
        from repro.models.attention import slot_gather
        axes = self.slot_batch_axes(1)
        out = dict(cache)
        for key, leaf in cache.items():
            if key == "pos":
                out[key] = leaf[slot]
            else:
                out[key] = slot_gather(leaf, slot, axes[key])
        return out

    # -- paged caches (block-paged KV, vLLM-style) --------------------------

    def supports_paged(self) -> bool:
        """Whether the block-paged decode path covers this config (full
        per-position dense/MoE caches only; see transformer.supports_paged)."""
        return transformer.supports_paged(self.cfg)

    def paged_cache_shapes(self, n_blocks: int, block_size: int
                           ) -> dict[str, jax.ShapeDtypeStruct]:
        """Abstract paged KV pools: ``n_blocks`` physical blocks of
        ``block_size`` tokens each, shared by every sequence on the
        instance.  This is the layout ``MemoryModel``/MRA admission
        accounts — real block bytes, not per-slot ``max_len`` rows."""
        if not self.supports_paged():
            raise NotImplementedError(
                f"{self.cfg.name}: paged KV needs a full-cache dense/moe "
                f"config")
        cfg = self.cfg
        l, kv, dh = cfg.n_layers, cfg.n_kv_heads, cfg.dh
        sds = jax.ShapeDtypeStruct
        from repro.models.attention import kv_int8_enabled
        if kv_int8_enabled(cfg):
            return {
                "k": sds((l, n_blocks, block_size, kv, dh), jnp.int8),
                "v": sds((l, n_blocks, block_size, kv, dh), jnp.int8),
                "k_scale": sds((l, n_blocks, block_size, kv, 1),
                               jnp.bfloat16),
                "v_scale": sds((l, n_blocks, block_size, kv, 1),
                               jnp.bfloat16),
            }
        return {
            "k": sds((l, n_blocks, block_size, kv, dh), jnp.bfloat16),
            "v": sds((l, n_blocks, block_size, kv, dh), jnp.bfloat16),
        }

    def paged_cache_names(self, n_blocks: int, block_size: int
                          ) -> dict[str, tuple]:
        """Logical dimension names matching ``paged_cache_shapes``: the
        kv-head axis shards over TP when divisible (GQA replicates
        otherwise); physical blocks stay local — the sequence-sharded slab
        layout is the opt-in ``distributed.seqshard`` seam."""
        return {key: ("layers", None, None, "kv_heads", None)
                for key in self.paged_cache_shapes(n_blocks, block_size)}

    def kv_block_bytes(self, block_size: int) -> int:
        """Physical bytes of ONE paged KV block across all layers/leaves —
        the unit the admission budget and bytes-in-use metrics count in."""
        total = 0
        for s in self.paged_cache_shapes(1, block_size).values():
            total += int(np.prod(s.shape)) * np.dtype(s.dtype).itemsize
        return total

    def dense_kv_bytes(self, batch: int, max_len: int) -> int:
        """Bytes of the dense slot-pool reservation (``init_slot_cache``)
        for the same capacity — the baseline paged KV is measured against."""
        total = 0
        for s in self.cache_shapes(batch, max_len).values():
            total += int(np.prod(s.shape)) * np.dtype(s.dtype).itemsize
        return total

    def kv_cache_bytes(self, *, batching: str, max_batch: int, max_len: int,
                       block_size: int = 16,
                       n_kv_blocks: Optional[int] = None) -> int:
        """Decode-cache bytes one instance reserves under ``batching`` —
        what memory admission should charge on top of weights/framework."""
        if batching == "paged":
            n_blocks = (n_kv_blocks if n_kv_blocks is not None
                        else default_kv_blocks(max_batch, max_len,
                                               block_size))
            return n_blocks * self.kv_block_bytes(block_size)
        return self.dense_kv_bytes(max_batch, max_len)

    def init_paged_cache(self, n_blocks: int, block_size: int) -> Any:
        """Real zeroed paged KV pools (block 0 is the engine's null block)."""
        return jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype),
            self.paged_cache_shapes(n_blocks, block_size))

    def append_paged(self, cache: Any, entry: Any, block_row: jax.Array
                     ) -> Any:
        """Scatter a batch-1 prefill cache ``entry`` into physical pages.

        ``entry`` is a cache returned by ``prefill`` (dense layout,
        ``max_len`` rows, ``max_len % block_size == 0``); logical block i
        of the entry lands in physical block ``block_row[i]``.  Write
        contract under prefix sharing: every written entry of
        ``block_row`` must be an exclusively-owned (refcount-1) block —
        the engine asserts this host-side before dispatch.  Row entries
        set to the DROP SENTINEL (``n_blocks``, one past the pool — it
        must stay positive, a negative index would be normalised back
        onto a live block) suppress the write entirely via scatter
        ``mode="drop"``: shared prefix blocks and padding rows are
        skipped, never written.  jit-compatible: ``block_row`` may be
        traced, so admitting different requests reuses one executable.
        """
        out = dict(cache)
        for key, pages in cache.items():
            leaf = entry[key][:, 0]  # (L, max_len, ...) — batch-1 squeeze
            l, s = leaf.shape[:2]
            bs = pages.shape[2]
            blocks = leaf.reshape(l, s // bs, bs, *leaf.shape[2:])
            out[key] = pages.at[:, block_row].set(blocks.astype(pages.dtype),
                                                  mode="drop")
        return out

    def copy_block(self, cache: Any, src: jax.Array, dst: jax.Array) -> Any:
        """Copy one physical page across every KV leaf (copy-on-write
        resolution): the sequence diverging from a shared prompt-tail
        block gets a private copy before its first append lands.
        jit-compatible with donated ``cache``; ``src``/``dst`` may be
        traced so every COW reuses one executable."""
        out = dict(cache)
        for key, pages in cache.items():
            out[key] = pages.at[:, dst].set(pages[:, src])
        return out

    def gather_pages(self, cache: Any, block_row: jax.Array,
                     pos: jax.Array) -> Any:
        """Rebuild one sequence as a contiguous batch-1 dense cache — the
        inverse of ``append_paged`` (tests, migration, slot merging)."""
        out = {}
        for key, pages in cache.items():
            g = pages[:, block_row]  # (L, M, bs, ...)
            l, m, bs = g.shape[:3]
            out[key] = g.reshape(l, 1, m * bs, *g.shape[3:])
        out["pos"] = jnp.asarray(pos, jnp.int32)
        return out

    def decode_step_paged(self, params, token, cache, block_tables, pos):
        return transformer.decode_step_paged(params, token, cache,
                                             block_tables, pos, self.cfg)

    # -- stubbed modality frontends -----------------------------------------

    def needs_ctx(self) -> bool:
        return self.cfg.family in ("encdec", "vlm")

    def ctx_shape(self, batch: int) -> Optional[jax.ShapeDtypeStruct]:
        if not self.needs_ctx():
            return None
        return jax.ShapeDtypeStruct(
            (batch, self.cfg.n_context_tokens, self.cfg.d_model),
            jnp.bfloat16)


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg=cfg)


# --------------------------------------------------------------------------
# Dry-run input specs (ShapeDtypeStruct stand-ins, no allocation)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeCase:
    """One assigned input-shape cell."""

    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPE_CASES = {
    "train_4k": ShapeCase("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCase("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCase("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCase("long_500k", "decode", 524288, 1),
}


def input_specs(model: Model, case: ShapeCase
                ) -> tuple[dict[str, Any], dict[str, Any]]:
    """Returns (ShapeDtypeStruct tree, logical-names tree) for the step."""
    sds = jax.ShapeDtypeStruct
    b, s = case.global_batch, case.seq_len
    i32 = jnp.int32
    if case.kind == "train":
        specs = {"tokens": sds((b, s), i32), "labels": sds((b, s), i32)}
        names = {"tokens": ("batch", "seq"), "labels": ("batch", "seq")}
    elif case.kind == "prefill":
        specs = {"tokens": sds((b, s), i32)}
        names = {"tokens": ("batch", "seq")}
    else:  # decode
        specs = {"token": sds((b,), i32),
                 "cache": model.cache_shapes(b, s)}
        names = {"token": ("batch",),
                 "cache": model.cache_names(b, s)}
    if model.needs_ctx() and case.kind != "decode":
        specs["ctx"] = model.ctx_shape(b)
        names["ctx"] = ("batch", "seq", None)
    return specs, names
