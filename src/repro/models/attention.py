"""GQA attention blocks: projections, full-sequence and decode paths, caches.

Cache conventions (see kvcache.py):
  * full cache:   (B, S_max, K, Dh), write slot = position.
  * rolled cache: (B, W, K, Dh) for sliding-window layers, slot = pos % W;
    slot contents are reconstructible from the current position, so no
    per-slot position array is needed.

Rotary embeddings are applied before caching (post-rope keys in cache).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import current_mesh, named, serve_tp
from repro.kernels import ops
from repro.models.config import ModelConfig
from repro.models.layers import PSpec, apply_rope

NEG_INF = -1e30


def _baseline_mode() -> bool:
    """REPRO_BASELINE=1 disables the beyond-paper perf fixes so §Perf can
    measure baseline vs. optimized with identical analysis code."""
    import os
    return os.environ.get("REPRO_BASELINE", "") == "1"


def _tp_size() -> int:
    mesh = current_mesh()
    return int(mesh.shape.get("model", 1)) if mesh is not None else 1


def _shard_heads(q: jax.Array, k: jax.Array, v: jax.Array
                 ) -> tuple[jax.Array, jax.Array, jax.Array, int]:
    """Make full-sequence attention shard over the TP axis for ANY head
    count (§Perf iteration A1, beyond-paper).

    Head counts that don't divide the model axis (qwen2's 28q/4kv, hymba's
    25q/5kv) leave XLA no head sharding, so it *replicates the whole
    attention computation 16x*.  Fix: pad Q heads to the next multiple of
    TP and expand K/V to one kv head per (padded) Q head — the flash einsum
    then has a head axis every mesh size divides.  The K/V expansion is
    free at the FLOP level and its extra bytes are sharded away by the very
    axis it unlocks; padded-head outputs are sliced off.

    Returns (q', k', v', n_heads_orig).
    """
    tp = _tp_size()
    b, sq, h, d = q.shape
    n_kv = k.shape[2]
    if tp == 1 or (h % tp == 0 and n_kv % tp == 0) or _baseline_mode():
        return q, k, v, h
    h_pad = -(-h // tp) * tp
    g = h // n_kv
    # kv head serving q head i is i // g; padded heads reuse head 0.
    kv_idx = jnp.concatenate([jnp.arange(h) // g,
                              jnp.zeros((h_pad - h,), jnp.int32)])
    if h_pad != h:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, h_pad - h), (0, 0)))
    k = jnp.take(k, kv_idx, axis=2)
    v = jnp.take(v, kv_idx, axis=2)
    q = named(q, "batch", "seq", "heads", None)
    k = named(k, "batch", "seq", "heads", None)
    v = named(v, "batch", "seq", "heads", None)
    return q, k, v, h


def attn_specs(cfg: ModelConfig, cross: bool = False) -> dict[str, PSpec]:
    d, hq, kv = cfg.d_model, cfg.q_dim, cfg.kv_dim
    s = {
        "wq": PSpec((d, hq), ("fsdp", "tp")),
        "wk": PSpec((d, kv), ("fsdp", "tp")),
        "wv": PSpec((d, kv), ("fsdp", "tp")),
        "wo": PSpec((hq, d), ("tp", "fsdp")),
    }
    if cfg.qkv_bias and not cross:
        s["bq"] = PSpec((hq,), ("tp",), init="zeros")
        s["bk"] = PSpec((kv,), ("tp",), init="zeros")
        s["bv"] = PSpec((kv,), ("tp",), init="zeros")
    return s


def _project_q(params: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    q = x @ params["wq"]
    if "bq" in params:
        q = q + params["bq"]
    b, s, _ = x.shape
    q = q.reshape(b, s, cfg.n_heads, cfg.dh)
    return named(q, "batch", "seq", "heads", None)


def _project_kv(params: dict, x: jax.Array, cfg: ModelConfig
                ) -> tuple[jax.Array, jax.Array]:
    k = x @ params["wk"]
    v = x @ params["wv"]
    if "bk" in params:
        k = k + params["bk"]
        v = v + params["bv"]
    b, s, _ = x.shape
    k = k.reshape(b, s, cfg.n_kv_heads, cfg.dh)
    v = v.reshape(b, s, cfg.n_kv_heads, cfg.dh)
    return (named(k, "batch", "seq", "kv_heads", None),
            named(v, "batch", "seq", "kv_heads", None))


def _output(params: dict, o: jax.Array) -> jax.Array:
    b, s, h, dh = o.shape
    o = named(o, "batch", "seq", "heads", None)
    o = o.reshape(b, s, h * dh)
    if serve_tp() > 1:
        # Serving TP is column-only/exact: gather the head shards BEFORE
        # the output projection so wo's contraction runs in full on every
        # device — an all-gather is bitwise-exact, a split-K all-reduce
        # is not (bf16 reassociation flips near-tie argmax tokens).
        o = named(o, "batch", "seq", None)
    out = o @ params["wo"]
    return named(out, "batch", "seq", None)


# --------------------------------------------------------------------------
# Full-sequence (training / prefill)
# --------------------------------------------------------------------------


def attn_full(params: dict, x: jax.Array, cfg: ModelConfig, *,
              positions: jax.Array, window: Optional[int] = None,
              causal: bool = True, block_q: int = 512, block_k: int = 512
              ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Self-attention over the whole sequence.

    Returns (output, k, v) — k/v post-rope, for the caller to cache.
    """
    q = _project_q(params, x, cfg)
    k, v = _project_kv(params, x, cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    qs, ks, vs, h = _shard_heads(q, k, v)
    o = ops.flash_attention(qs, ks, vs, causal=causal, window=window,
                            block_q=block_q, block_k=block_k)
    return _output(params, o[:, :, :h]), k, v


def cross_attn_full(params: dict, x: jax.Array, context_kv: tuple,
                    cfg: ModelConfig) -> jax.Array:
    """Cross-attention to precomputed context k/v (no mask, no rope)."""
    q = _project_q(params, x, cfg)
    k, v = context_kv
    qs, ks, vs, h = _shard_heads(q, k, v)
    o = ops.flash_attention(qs, ks, vs, causal=False)
    return _output(params, o[:, :, :h])


def context_kv(params: dict, ctx: jax.Array, cfg: ModelConfig
               ) -> tuple[jax.Array, jax.Array]:
    """Project encoder/image context into this layer's k/v (cacheable)."""
    return _project_kv(params, ctx, cfg)


# --------------------------------------------------------------------------
# int8 KV-cache quantization (§Perf D — decode cells are KV-bandwidth bound)
# --------------------------------------------------------------------------


def kv_int8_enabled(cfg: ModelConfig) -> bool:
    """REPRO_KV_INT8=1 stores full (non-rolled) dense/MoE KV caches as int8
    with per-(position, kv-head) scales — halves decode HBM traffic."""
    import os
    return (os.environ.get("REPRO_KV_INT8", "") == "1"
            and cfg.family in ("dense", "moe")
            and cfg.sliding_window is None
            and cfg.local_global_ratio == 0)


def kv_quantize(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(B,S,K,D) bf16 -> (int8 codes, (B,S,K,1) bf16 scales)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.abs(xf).max(axis=-1, keepdims=True) / 127.0,
                        1e-8)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def kv_dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return (q.astype(jnp.float32)
            * scale.astype(jnp.float32)).astype(jnp.bfloat16)


# --------------------------------------------------------------------------
# Decode (one token against a cache)
# --------------------------------------------------------------------------


def cache_write(cache: jax.Array, new: jax.Array, slot: jax.Array) -> jax.Array:
    """Write (B, 1, K, Dh) into (B, C, K, Dh) at ``slot`` (scalar or (B,))."""
    if slot.ndim == 0:
        return jax.lax.dynamic_update_slice(
            cache, new.astype(cache.dtype), (0, slot, 0, 0))
    return jax.vmap(
        lambda c, n, s: jax.lax.dynamic_update_slice(c, n, (s, 0, 0))
    )(cache, new.astype(cache.dtype), slot)


def slot_gather(leaf: jax.Array, slot: jax.Array, batch_axis: int
                ) -> jax.Array:
    """Extract one decode slot as a batch-1 leaf (inverse of a slot merge).

    Used by the continuous-batching engine to inspect / migrate a single
    request's cache entry out of the persistent slot pool.
    """
    return jax.lax.dynamic_slice_in_dim(leaf, slot, 1, axis=batch_axis)


def _rolled_decode(q, kc, vc, pos, window):
    """Attention against a rolled cache: slot s holds position
    pos - ((pos - s) mod C); invalid when that position is negative."""
    b, _, h, d = q.shape
    c = kc.shape[1]
    n_kv = kc.shape[2]
    qf = q.astype(jnp.float32).reshape(b, 1, n_kv, h // n_kv, d) * d ** -0.5
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qf, kc.astype(jnp.float32))
    slots = jnp.arange(c)
    pos_b = jnp.broadcast_to(jnp.atleast_1d(pos), (b,))
    slot_pos = pos_b[:, None] - jnp.mod(pos_b[:, None] - slots[None, :], c)
    valid = slot_pos >= 0
    if window is not None and window < c:
        valid &= slot_pos > pos_b[:, None] - window
    scores = jnp.where(valid[:, None, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, vc.astype(jnp.float32))
    return o.reshape(b, 1, h, d).astype(q.dtype)


def attn_decode(params: dict, x: jax.Array, kc: jax.Array, vc: jax.Array,
                pos: jax.Array, cfg: ModelConfig, *,
                rolled: bool = False, window: Optional[int] = None
                ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One-token self-attention against (and updating) the cache.

    x: (B, 1, D); pos: scalar or (B,) absolute position of the new token.
    Returns (output, kc', vc').
    """
    b = x.shape[0]
    pos_arr = jnp.asarray(pos)
    positions = jnp.broadcast_to(jnp.atleast_1d(pos_arr), (b,))[:, None]
    q = _project_q(params, x, cfg)
    k, v = _project_kv(params, x, cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    c = kc.shape[1]
    slot = jnp.mod(pos_arr, c) if rolled else pos_arr
    kc = cache_write(kc, k, slot)
    vc = cache_write(vc, v, slot)
    if rolled:
        o = _rolled_decode(q, kc, vc, pos_arr, window)
    else:
        cache_len = jnp.broadcast_to(jnp.atleast_1d(pos_arr), (b,)) + 1
        o = ops.decode_attention(q, kc, vc, cache_len.astype(jnp.int32),
                                 window=window)
    return _output(params, o), kc, vc


def paged_cache_write(pages: jax.Array, new: jax.Array,
                      block_tables: jax.Array, pos: jax.Array,
                      active: Optional[jax.Array] = None) -> jax.Array:
    """Write one token's (B, 1, K, Dh) K/V into (N, bs, K, Dh) pages.

    Each sequence's row lands in physical block ``tables[b, pos[b]//bs]``
    at offset ``pos[b] % bs``.  Live sequences own disjoint WRITABLE
    blocks, so the scatter never collides; free decode slots all target
    the shared null block, whose contents are never attended.  Under
    prefix sharing the write contract is stricter: the block a sequence
    writes must be exclusively owned (refcount 1) — the engine resolves
    copy-on-write and asserts that before every dispatched round, so a
    shared (refcount > 1) block is never named by a write-position row
    of ``block_tables``.

    ``active`` ((B,) int32/bool, optional) drops inactive sequences' rows
    entirely (scatter ``mode="drop"`` on an out-of-range block index)
    instead of scattering them into the null block — free decode slots in
    the fused hot path then write nothing at all, so the null page stays
    zero and the scatter never has colliding free-slot rows.  The drop
    sentinel must be ``>= n_blocks``: a negative index would be
    NORMALIZED (to the last physical block — a live sequence's page)
    before out-of-bounds handling ever sees it.
    """
    bs = pages.shape[1]
    blk = jnp.take_along_axis(block_tables, (pos // bs)[:, None], axis=1)[:, 0]
    if active is not None:
        blk = jnp.where(active.astype(bool), blk, pages.shape[0])
    return pages.at[blk, pos % bs].set(new[:, 0].astype(pages.dtype),
                                       mode="drop")


def attn_decode_paged(params: dict, x: jax.Array,
                      k_pages: jax.Array, v_pages: jax.Array,
                      block_tables: jax.Array, pos: jax.Array,
                      cfg: ModelConfig,
                      active: Optional[jax.Array] = None
                      ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One-token self-attention against (and updating) a paged cache.

    x: (B, 1, D); k_pages/v_pages: (N, bs, K, Dh) physical blocks shared
    by the whole batch; block_tables: (B, M) int32; pos: (B,) absolute
    position of each sequence's new token; ``active`` optionally masks
    free slots' writes out (see paged_cache_write).  Returns
    (output, k', v').
    """
    positions = pos[:, None]
    q = _project_q(params, x, cfg)
    k, v = _project_kv(params, x, cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    k_pages = paged_cache_write(k_pages, k, block_tables, pos, active)
    v_pages = paged_cache_write(v_pages, v, block_tables, pos, active)
    cache_len = (pos + 1).astype(jnp.int32)
    o = ops.paged_decode_attention(q, k_pages, v_pages, block_tables,
                                   cache_len)
    return _output(params, o), k_pages, v_pages


def cache_write_window(cache: jax.Array, new: jax.Array, start: jax.Array
                       ) -> jax.Array:
    """Write (B, W, K, Dh) into (B, C, K, Dh) at per-sequence row ``start``
    (a (B,) vector) — the W-row generalization of ``cache_write`` used by
    the speculative verify step.  Requires ``start + W <= C`` (the engine
    reserves the +k speculation margin at submit time); XLA's clamped
    start would otherwise silently shift the window."""
    return jax.vmap(
        lambda c, n, s: jax.lax.dynamic_update_slice(c, n, (s, 0, 0))
    )(cache, new.astype(cache.dtype), start)


def attn_verify(params: dict, x: jax.Array, kc: jax.Array, vc: jax.Array,
                pos: jax.Array, cfg: ModelConfig
                ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """W-token verify attention against (and updating) a dense cache.

    x: (B, W, D) — the speculative window [last accepted token, k draft
    tokens]; pos: (B,) absolute position of the window start.  Writes the
    window's K/V rows at pos..pos+W-1 and attends them with the
    per-query-row causal mask (window query j sees rows < pos + j + 1).
    Returns (output (B, W, D), kc', vc').
    """
    b, w, _ = x.shape
    pos_b = jnp.broadcast_to(jnp.atleast_1d(jnp.asarray(pos)), (b,))
    positions = pos_b[:, None] + jnp.arange(w)[None, :]
    q = _project_q(params, x, cfg)
    k, v = _project_kv(params, x, cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    kc = cache_write_window(kc, k, pos_b)
    vc = cache_write_window(vc, v, pos_b)
    o = ops.verify_attention(q, kc, vc, pos_b.astype(jnp.int32))
    return _output(params, o), kc, vc


def attn_verify_paged(params: dict, x: jax.Array,
                      k_pages: jax.Array, v_pages: jax.Array,
                      block_tables: jax.Array, pos: jax.Array,
                      cfg: ModelConfig,
                      active: Optional[jax.Array] = None
                      ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """W-token verify attention against (and updating) a paged cache.

    Scatters the window's rows one position at a time (W is small — the
    draft length plus one) through ``paged_cache_write`` so inactive
    slots' rows drop and the COW write contract stays per-position, then
    attends with the per-query-row causal mask.
    """
    w = x.shape[1]
    positions = pos[:, None] + jnp.arange(w)[None, :]
    q = _project_q(params, x, cfg)
    k, v = _project_kv(params, x, cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    for j in range(w):
        k_pages = paged_cache_write(k_pages, k[:, j:j + 1], block_tables,
                                    pos + j, active)
        v_pages = paged_cache_write(v_pages, v[:, j:j + 1], block_tables,
                                    pos + j, active)
    o = ops.paged_verify_attention(q, k_pages, v_pages, block_tables,
                                   pos.astype(jnp.int32))
    return _output(params, o), k_pages, v_pages


def attn_decode_paged_quant(params: dict, x: jax.Array,
                            k_pages: jax.Array, v_pages: jax.Array,
                            ks_pages: jax.Array, vs_pages: jax.Array,
                            block_tables: jax.Array, pos: jax.Array,
                            cfg: ModelConfig,
                            active: Optional[jax.Array] = None
                            ) -> tuple[jax.Array, jax.Array, jax.Array,
                                       jax.Array, jax.Array]:
    """attn_decode_paged against int8 code + scale pages (§Perf D)."""
    positions = pos[:, None]
    q = _project_q(params, x, cfg)
    k, v = _project_kv(params, x, cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    k8, ks_new = kv_quantize(k)
    v8, vs_new = kv_quantize(v)
    k_pages = paged_cache_write(k_pages, k8, block_tables, pos, active)
    v_pages = paged_cache_write(v_pages, v8, block_tables, pos, active)
    ks_pages = paged_cache_write(ks_pages, ks_new, block_tables, pos, active)
    vs_pages = paged_cache_write(vs_pages, vs_new, block_tables, pos, active)
    cache_len = (pos + 1).astype(jnp.int32)
    o = ops.paged_decode_attention_quant(q, k_pages, v_pages, ks_pages,
                                         vs_pages, block_tables, cache_len)
    return _output(params, o), k_pages, v_pages, ks_pages, vs_pages


def attn_decode_quant(params: dict, x: jax.Array,
                      kc: jax.Array, vc: jax.Array,
                      ksc: jax.Array, vsc: jax.Array,
                      pos: jax.Array, cfg: ModelConfig
                      ) -> tuple[jax.Array, jax.Array, jax.Array,
                                 jax.Array, jax.Array]:
    """attn_decode against int8 caches (kc/vc int8, ksc/vsc (B,C,K,1)
    scales).  The dequantize fuses into the attention consumer, so HBM
    reads stay int8-sized; on the TPU target the Pallas decode kernel
    takes the int8 refs directly."""
    b = x.shape[0]
    pos_arr = jnp.asarray(pos)
    positions = jnp.broadcast_to(jnp.atleast_1d(pos_arr), (b,))[:, None]
    q = _project_q(params, x, cfg)
    k, v = _project_kv(params, x, cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    k8, ks_new = kv_quantize(k)
    v8, vs_new = kv_quantize(v)
    kc = cache_write(kc, k8, pos_arr)
    vc = cache_write(vc, v8, pos_arr)
    ksc = cache_write(ksc, ks_new, pos_arr)
    vsc = cache_write(vsc, vs_new, pos_arr)
    cache_len = (jnp.broadcast_to(jnp.atleast_1d(pos_arr), (b,)) + 1
                 ).astype(jnp.int32)
    o = ops.decode_attention_quant(q, kc, vc, ksc, vsc, cache_len)
    return _output(params, o), kc, vc, ksc, vsc


def cross_attn_decode(params: dict, x: jax.Array,
                      ck: jax.Array, cv: jax.Array,
                      cfg: ModelConfig) -> jax.Array:
    """One-token cross-attention against a precomputed context cache."""
    q = _project_q(params, x, cfg)
    b = x.shape[0]
    s_ctx = ck.shape[1]
    cache_len = jnp.full((b,), s_ctx, jnp.int32)
    o = ops.decode_attention(q, ck, cv, cache_len)
    return _output(params, o)
