"""Encoder-decoder backbone (seamless-m4t-large-v2).

The audio frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed frame embeddings (B, S_enc, d_model); the encoder is a
bidirectional transformer over them, the decoder a causal transformer with
per-layer cross-attention.  Decode caches: per-layer self k/v (full length)
plus per-layer projected cross k/v (computed once from the encoder output).
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import named
from repro.models import attention as attn
from repro.models.config import ModelConfig
from repro.models.layers import (PSpec, mlp_apply, mlp_specs, rms_norm,
                                 stack_tree)
from repro.models.transformer import _full_cache, lm_head


def enc_block_specs(cfg: ModelConfig) -> dict[str, Any]:
    d = cfg.d_model
    return {
        "ln1": PSpec((d,), (None,), init="zeros"),
        "attn": attn.attn_specs(cfg),
        "ln2": PSpec((d,), (None,), init="zeros"),
        "mlp": mlp_specs(d, cfg.d_ff, cfg.mlp),
    }


def dec_block_specs(cfg: ModelConfig) -> dict[str, Any]:
    d = cfg.d_model
    return {
        "ln1": PSpec((d,), (None,), init="zeros"),
        "attn": attn.attn_specs(cfg),
        "ln_x": PSpec((d,), (None,), init="zeros"),
        "xattn": attn.attn_specs(cfg, cross=True),
        "ln2": PSpec((d,), (None,), init="zeros"),
        "mlp": mlp_specs(d, cfg.d_ff, cfg.mlp),
    }


def encdec_specs(cfg: ModelConfig) -> dict[str, Any]:
    d, v = cfg.d_model, cfg.padded_vocab
    return {
        "embed": PSpec((v, d), ("vocab", "fsdp"), init="small"),
        "enc_layers": stack_tree(enc_block_specs(cfg), cfg.encoder_layers),
        "enc_ln": PSpec((d,), (None,), init="zeros"),
        "layers": stack_tree(dec_block_specs(cfg), cfg.n_layers),
        "ln_f": PSpec((d,), (None,), init="zeros"),
        "head": PSpec((d, v), ("fsdp", "vocab")),
    }


def encode(params: dict, ctx: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Bidirectional encoder over precomputed frame embeddings."""
    x = named(ctx, "batch", "seq", None)
    positions = jnp.arange(x.shape[1])

    def body(x, lp):
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        a, _, _ = attn.attn_full(lp["attn"], h, cfg, positions=positions,
                                 causal=False)
        x = x + a
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = named(x + mlp_apply(lp["mlp"], h, cfg.mlp), "batch", "seq", None)
        return x, None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return rms_norm(x, params["enc_ln"], cfg.norm_eps)


def _dec_block_full(lp, x, enc_out, positions, cfg):
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    a, k, v = attn.attn_full(lp["attn"], h, cfg, positions=positions)
    x = x + a
    h = rms_norm(x, lp["ln_x"], cfg.norm_eps)
    ck, cv = attn.context_kv(lp["xattn"], enc_out, cfg)
    x = x + attn.cross_attn_full(lp["xattn"], h, (ck, cv), cfg)
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    x = named(x + mlp_apply(lp["mlp"], h, cfg.mlp), "batch", "seq", None)
    return x, k, v, ck, cv


def forward(params: dict, tokens: jax.Array, cfg: ModelConfig, *,
            ctx: Optional[jax.Array] = None, remat: bool = False,
            train: bool = True) -> tuple[jax.Array, jax.Array]:
    assert ctx is not None, "enc-dec forward needs encoder embeddings"
    enc_out = encode(params, ctx, cfg)
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    x = named(x, "batch", "seq", None)
    positions = jnp.arange(s)

    def body(x, lp):
        x, _, _, _, _ = _dec_block_full(lp, x, enc_out, positions, cfg)
        return x, None

    if remat:
        body = jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)
    x, _ = jax.lax.scan(body, x, params["layers"])
    return lm_head(params, x, cfg), jnp.zeros((), jnp.float32)


def prefill(params: dict, tokens: jax.Array, cfg: ModelConfig, *,
            max_len: Optional[int] = None, ctx: Optional[jax.Array] = None
            ) -> tuple[jax.Array, dict]:
    assert ctx is not None
    enc_out = encode(params, ctx, cfg)
    b, s = tokens.shape
    max_len = max_len or s
    x = jnp.take(params["embed"], tokens, axis=0)
    positions = jnp.arange(s)

    def body(x, lp):
        x, k, v, ck, cv = _dec_block_full(lp, x, enc_out, positions, cfg)
        return x, (_full_cache(k, max_len), _full_cache(v, max_len), ck, cv)

    x, (ks, vs, cks, cvs) = jax.lax.scan(body, x, params["layers"])
    cache = {"k": ks, "v": vs, "cross_k": cks, "cross_v": cvs,
             "pos": jnp.full((), s, jnp.int32)}
    return lm_head(params, x[:, -1:, :], cfg)[:, 0], cache


def decode_step(params: dict, token: jax.Array, cache: dict,
                cfg: ModelConfig) -> tuple[jax.Array, dict]:
    pos = cache["pos"]
    x = jnp.take(params["embed"], token[:, None], axis=0)

    def body(x, xs):
        lp, kc, vc, ck, cv = xs
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        a, kc, vc = attn.attn_decode(lp["attn"], h, kc, vc, pos, cfg)
        x = x + a
        h = rms_norm(x, lp["ln_x"], cfg.norm_eps)
        x = x + attn.cross_attn_decode(lp["xattn"], h, ck, cv, cfg)
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + mlp_apply(lp["mlp"], h, cfg.mlp)
        return x, (kc, vc)

    x, (kn, vn) = jax.lax.scan(
        body, x, (params["layers"], cache["k"], cache["v"],
                  cache["cross_k"], cache["cross_v"]))
    logits = lm_head(params, x, cfg)[:, 0]
    return logits, dict(cache, k=kn, v=vn, pos=pos + 1)
