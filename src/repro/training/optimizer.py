"""AdamW with global-norm clipping (pure JAX, pytree state).

Moments are fp32 regardless of param dtype; the update is computed in fp32
and cast back.  State leaves inherit the param's logical sharding names so
optimizer state shards identically to params (FSDP).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    schedule: str = "cosine"  # cosine | constant
    total_steps: int = 10_000

    def init(self, params: Any) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            m=jax.tree_util.tree_map(zeros, params),
            v=jax.tree_util.tree_map(zeros, params),
        )

    def _lr_at(self, step: jax.Array) -> jax.Array:
        warm = jnp.minimum(1.0, (step + 1) / max(self.warmup_steps, 1))
        if self.schedule == "cosine":
            frac = jnp.clip(step / max(self.total_steps, 1), 0.0, 1.0)
            decay = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        else:
            decay = 1.0
        return self.lr * warm * decay

    def update(self, grads: Any, state: AdamWState, params: Any
               ) -> tuple[Any, AdamWState, dict[str, jax.Array]]:
        gf = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
        gnorm = jnp.sqrt(sum(
            jnp.sum(g * g) for g in jax.tree_util.tree_leaves(gf)))
        scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9))
        step = state.step + 1
        lr = self._lr_at(state.step)
        b1c = 1.0 - self.b1 ** step.astype(jnp.float32)
        b2c = 1.0 - self.b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g * scale
            m = self.b1 * m + (1 - self.b1) * g
            v = self.b2 * v + (1 - self.b2) * g * g
            mh = m / b1c
            vh = v / b2c
            delta = mh / (jnp.sqrt(vh) + self.eps)
            if p.ndim >= 2:  # decoupled weight decay on matrices only
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
            return new_p, m, v

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(gf)
        flat_m = treedef.flatten_up_to(state.m)
        flat_v = treedef.flatten_up_to(state.v)
        out = [upd(p, g, m, v) for p, g, m, v in
               zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
        new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
        new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
        metrics = {"grad_norm": gnorm, "lr": lr}
        return new_p, AdamWState(step=step, m=new_m, v=new_v), metrics
