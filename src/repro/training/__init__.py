from repro.training.optimizer import AdamW, AdamWState
from repro.training.train_loop import (TrainStepConfig, cross_entropy,
                                       make_train_step, train)

__all__ = ["AdamW", "AdamWState", "TrainStepConfig", "cross_entropy",
           "make_train_step", "train"]
