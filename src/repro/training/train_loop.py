"""Train-step factory and loop: microbatch accumulation, remat, optional
bf16 gradient compression, checkpoint/restart fault tolerance."""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterator, Optional

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.training.optimizer import AdamW, AdamWState


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean next-token NLL.  logits fp32 (B,S,V); labels (B,S) int32."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None].astype(jnp.int32),
                               axis=-1)[..., 0]
    return nll.mean()


@dataclasses.dataclass(frozen=True)
class TrainStepConfig:
    microbatches: int = 1
    remat: bool = True
    grad_compress: bool = False  # bf16 gradient accumulation/all-reduce
    aux_weight: float = 0.01  # MoE load-balance loss weight


def make_train_step(model: Model, opt: AdamW,
                    cfg: TrainStepConfig = TrainStepConfig()
                    ) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params', state', metrics).

    ``batch``: {"tokens": (B,S), "labels": (B,S)[, "ctx": (B,Sc,D)]}.
    With ``microbatches > 1`` the global batch is split along the batch dim
    and gradients accumulated in a lax.scan (activation memory / n).
    ``grad_compress`` accumulates (and therefore cross-device-reduces)
    gradients in bf16 — halves the gradient-reduction collective bytes at
    ~1 ulp cost, a standard distributed-training trick (DESIGN.md §5).
    """

    def loss_fn(params, tokens, labels, ctx):
        logits, aux = model.forward(params, tokens, ctx=ctx, remat=cfg.remat)
        return cross_entropy(logits, labels) + cfg.aux_weight * aux

    grad_dtype = jnp.bfloat16 if cfg.grad_compress else jnp.float32

    def train_step(params, opt_state: AdamWState, batch):
        tokens, labels = batch["tokens"], batch["labels"]
        ctx = batch.get("ctx")
        n_mb = cfg.microbatches
        if n_mb == 1:
            (loss, grads) = jax.value_and_grad(loss_fn)(params, tokens,
                                                        labels, ctx)
            grads = jax.tree_util.tree_map(
                lambda g: g.astype(grad_dtype), grads)
        else:
            b = tokens.shape[0]
            if b % n_mb:
                raise ValueError(f"batch {b} not divisible by {n_mb}")
            mb = lambda x: x.reshape(n_mb, b // n_mb, *x.shape[1:])
            toks, labs = mb(tokens), mb(labels)
            ctxs = mb(ctx) if ctx is not None else None
            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, grad_dtype), params)

            def acc_body(carry, xs):
                loss_acc, gacc = carry
                if ctxs is None:
                    t, l = xs
                    c = None
                else:
                    t, l, c = xs
                loss, grads = jax.value_and_grad(loss_fn)(params, t, l, c)
                gacc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(grad_dtype), gacc, grads)
                return (loss_acc + loss, gacc), None

            xs = (toks, labs) if ctxs is None else (toks, labs, ctxs)
            (loss, grads), _ = jax.lax.scan(
                acc_body, (jnp.zeros(()), zeros), xs)
            loss = loss / n_mb
            grads = jax.tree_util.tree_map(lambda g: g / n_mb, grads)
        new_params, new_state, metrics = opt.update(grads, opt_state, params)
        metrics = dict(metrics, loss=loss)
        return new_params, new_state, metrics

    return train_step


@dataclasses.dataclass
class TrainResult:
    losses: list[float]
    steps: int
    wall_time: float


def train(model: Model, params, batches: Iterator[dict], *,
          opt: Optional[AdamW] = None, steps: int = 100,
          step_cfg: TrainStepConfig = TrainStepConfig(),
          checkpoint_dir: Optional[str] = None, checkpoint_every: int = 50,
          log_every: int = 10,
          on_step: Optional[Callable[[int, dict], None]] = None
          ) -> tuple[Any, AdamWState, TrainResult]:
    """Simple single-process training loop with checkpoint/restart."""
    from repro.training import checkpoint as ckpt

    opt = opt or AdamW(total_steps=steps)
    opt_state = opt.init(params)
    start_step = 0
    if checkpoint_dir:
        restored = ckpt.restore_latest(checkpoint_dir, params, opt_state)
        if restored is not None:
            start_step, params, opt_state = restored

    step_fn = jax.jit(make_train_step(model, opt, step_cfg),
                      donate_argnums=(0, 1))
    losses: list[float] = []
    t0 = time.perf_counter()
    for step in range(start_step, steps):
        batch = next(batches)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if on_step:
            on_step(step, metrics)
        if log_every and step % log_every == 0:
            print(f"step {step:5d}  loss {loss:.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}")
        if checkpoint_dir and (step + 1) % checkpoint_every == 0:
            ckpt.save(checkpoint_dir, step + 1, params, opt_state)
    return params, opt_state, TrainResult(
        losses=losses, steps=steps - start_step,
        wall_time=time.perf_counter() - t0)
