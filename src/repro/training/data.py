"""Synthetic LM data pipeline: deterministic, seekable, shard-aware.

A structured synthetic language (repeating n-gram templates + noise) so a
~100M model shows a real, monotonic loss curve in a few hundred steps —
pure-uniform tokens would pin the loss at log(V).
"""

from __future__ import annotations

from typing import Iterator, Optional

import jax.numpy as jnp
import numpy as np


def make_batch(vocab: int, batch: int, seq: int, step: int, *,
               seed: int = 0, structure: int = 64) -> dict:
    """Deterministic batch for a given step (seekable -> restart-safe)."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    # Markov-ish structure: next token = (a*tok + b) % structure, with noise.
    a = 2 * rng.integers(1, structure // 2) + 1
    b = rng.integers(0, structure)
    toks = np.empty((batch, seq + 1), np.int64)
    toks[:, 0] = rng.integers(0, structure, batch)
    for t in range(seq):
        nxt = (a * toks[:, t] + b) % structure
        noise = rng.random(batch) < 0.1
        nxt = np.where(noise, rng.integers(0, structure, batch), nxt)
        toks[:, t + 1] = nxt
    toks = toks % vocab
    return {
        "tokens": jnp.asarray(toks[:, :-1], jnp.int32),
        "labels": jnp.asarray(toks[:, 1:], jnp.int32),
    }


def batch_iterator(vocab: int, batch: int, seq: int, *, seed: int = 0,
                   start_step: int = 0, ctx_shape: Optional[tuple] = None
                   ) -> Iterator[dict]:
    step = start_step
    rng = np.random.default_rng(seed + 1)
    while True:
        out = make_batch(vocab, batch, seq, step, seed=seed)
        if ctx_shape is not None:
            out["ctx"] = jnp.asarray(
                rng.normal(size=ctx_shape) * 0.02, jnp.bfloat16)
        yield out
        step += 1
