"""Checkpointing: atomic, keep-N, elastic restore onto any mesh.

Format: one ``.npz`` per checkpoint containing flattened leaves (params +
optimizer moments + step), written to a temp file and atomically renamed —
a crash mid-write never corrupts the latest checkpoint.  ``save_async``
offloads serialization to a daemon thread so the train loop is not blocked
(the standard overlap trick; the thread joins before the next save).

Restore returns host numpy trees; ``device_put_sharded_tree`` re-shards
them onto *any* target mesh — elastic scaling across restarts.
"""

from __future__ import annotations

import os
import re
import tempfile
import threading
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

from repro.training.optimizer import AdamWState

_SAVE_THREAD: Optional[threading.Thread] = None

BF16 = np.dtype(ml_dtypes.bfloat16)


def _flatten(tree: Any, prefix: str) -> dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = prefix + jax.tree_util.keystr(path)
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype == BF16:  # npz can't store bf16: uint16 bit view
            arr = arr.view(np.uint16)
            key = "~bf16~" + key
        out[key] = arr
    return out


def _unflatten(template: Any, arrays: dict[str, np.ndarray], prefix: str
               ) -> Any:
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, _ in flat:
        key = prefix + jax.tree_util.keystr(path)
        if key in arrays:
            leaves.append(arrays[key])
        else:
            leaves.append(arrays["~bf16~" + key].view(BF16))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save(ckpt_dir: str, step: int, params: Any,
         opt_state: Optional[AdamWState] = None, keep: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    payload = _flatten(params, "p")
    if opt_state is not None:
        payload.update(_flatten(opt_state.m, "m"))
        payload.update(_flatten(opt_state.v, "v"))
        payload["__opt_step"] = np.asarray(opt_state.step)
    payload["__step"] = np.asarray(step)
    final = os.path.join(ckpt_dir, f"ckpt_{step:08d}.npz")
    fd, tmp = tempfile.mkstemp(dir=ckpt_dir, suffix=".tmp")
    with os.fdopen(fd, "wb") as f:
        np.savez(f, **payload)
    os.replace(tmp, final)  # atomic
    _gc(ckpt_dir, keep)
    return final


def save_async(ckpt_dir: str, step: int, params: Any,
               opt_state: Optional[AdamWState] = None, keep: int = 3
               ) -> threading.Thread:
    """Snapshot to host, then write on a background thread."""
    global _SAVE_THREAD
    if _SAVE_THREAD is not None:
        _SAVE_THREAD.join()
    params_host = jax.device_get(params)
    opt_host = jax.device_get(opt_state) if opt_state is not None else None
    _SAVE_THREAD = threading.Thread(
        target=save, args=(ckpt_dir, step, params_host, opt_host, keep),
        daemon=True)
    _SAVE_THREAD.start()
    return _SAVE_THREAD


def wait_for_async_save() -> None:
    if _SAVE_THREAD is not None:
        _SAVE_THREAD.join()


def list_checkpoints(ckpt_dir: str) -> list[tuple[int, str]]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"ckpt_(\d+)\.npz", name)
        if m:
            out.append((int(m.group(1)), os.path.join(ckpt_dir, name)))
    return sorted(out)


def _gc(ckpt_dir: str, keep: int) -> None:
    ckpts = list_checkpoints(ckpt_dir)
    for _, path in ckpts[:-keep]:
        os.remove(path)


def restore(path: str, params_template: Any,
            opt_template: Optional[AdamWState] = None
            ) -> tuple[int, Any, Optional[AdamWState]]:
    with np.load(path) as z:
        arrays = {k: z[k] for k in z.files}
    step = int(arrays["__step"])
    params = _unflatten(params_template, arrays, "p")
    opt_state = None
    if opt_template is not None and "__opt_step" in arrays:
        opt_state = AdamWState(
            step=jax.numpy.asarray(arrays["__opt_step"]),
            m=_unflatten(opt_template.m, arrays, "m"),
            v=_unflatten(opt_template.v, arrays, "v"),
        )
    return step, params, opt_state


def restore_latest(ckpt_dir: str, params_template: Any = None,
                   opt_template: Optional[AdamWState] = None):
    """Returns (step, params, opt_state) or None.  Without a template the
    arrays come back as a flat dict (caller reshapes)."""
    ckpts = list_checkpoints(ckpt_dir)
    if not ckpts:
        return None
    _, path = ckpts[-1]
    if params_template is None:
        with np.load(path) as z:
            arrays = {k: z[k] for k in z.files}
        return int(arrays["__step"]), arrays, None
    return restore(path, params_template, opt_template)


def device_put_sharded_tree(tree: Any, shardings: Any) -> Any:
    """Elastic restore: place host arrays onto any mesh's shardings."""
    return jax.tree_util.tree_map(
        lambda x, s: jax.device_put(x, s), tree, shardings)
